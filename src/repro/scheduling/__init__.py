"""Priority-aware admission & scheduling in front of the Load Shedder.

Request lifecycle (who owns each hop):

    arrive   ServingEngine.enqueue          stamp arrival + SLO deadline
       |
    admit    scheduling.priorities          per-regime priority ladder
             scheduling.ratelimit           per-tenant token buckets
       |                                    (reject => explicit Response
       |                                     from the average-trust
       |                                     prior, admitted=False)
    queue    scheduling.queues              EDF within class, strict
       |                                    priority across classes,
       |                                    static-capacity backpressure
    batch    scheduling.batcher             coalesce queued candidate
       |                                    sets into one padded,
       |                                    budget-shaped micro-batch
    shed     core.shedder                   ONE three-regime shedding
       |                                    decision per micro-batch
       |                                    (EVAL / CACHED / PRIOR tiers)
    respond  scheduling.scheduler.drain     split per-request Responses;
                                            hedged re-dispatch via
                                            distribution.fault_tolerance

No *admitted* request is ever dropped: every item leaves with a trust
value (paper §5 invariant, preserved across the batching layer), and
every rejection is an observable ``Response`` with a reason — never
silence.
"""
from repro.scheduling.batcher import (MicroBatch, MicroBatcher,
                                      to_fused_inputs)
from repro.scheduling.priorities import (AdmissionPolicy, Priority,
                                         REASON_QUEUE_FULL,
                                         REASON_RATE_LIMITED,
                                         REASON_SHED_LOW_HEAVY,
                                         REASON_SHED_LOW_VERY_HEAVY,
                                         REASON_SHED_NORMAL_VERY_HEAVY)
from repro.scheduling.queues import (EDFQueue, PriorityQueueBank,
                                     QueuedRequest)
from repro.scheduling.ratelimit import TenantRateLimiter, TokenBucket
from repro.scheduling.scheduler import (Request, Response, Scheduler,
                                        SchedulerConfig, SchedulerStats)

__all__ = [
    "AdmissionPolicy", "Priority",
    "REASON_QUEUE_FULL", "REASON_RATE_LIMITED", "REASON_SHED_LOW_HEAVY",
    "REASON_SHED_LOW_VERY_HEAVY", "REASON_SHED_NORMAL_VERY_HEAVY",
    "EDFQueue", "PriorityQueueBank", "QueuedRequest",
    "TenantRateLimiter", "TokenBucket",
    "MicroBatch", "MicroBatcher", "to_fused_inputs",
    "Request", "Response", "Scheduler", "SchedulerConfig",
    "SchedulerStats",
]
