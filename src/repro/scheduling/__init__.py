"""Priority-aware admission & scheduling in front of the Load Shedder.

Request lifecycle (who owns each hop):

    arrive   ServingEngine.enqueue          stamp arrival + SLO deadline
       |
    admit    scheduling.priorities          per-regime priority ladder
             scheduling.ratelimit           per-tenant token buckets
       |                                    (reject => explicit Response
       |                                     from the average-trust
       |                                     prior, admitted=False)
    queue    scheduling.queues              EDF within class, strict
       |                                    priority across classes,
       |                                    static-capacity backpressure
    batch    scheduling.batcher             coalesce queued candidate
       |                                    sets into one padded,
       |                                    budget-shaped micro-batch
    shed     core.shedder                   ONE three-regime shedding
       |     (drain_mode="host")            decision per micro-batch
       |                                    (EVAL / CACHED / PRIOR tiers)
       |                                    via the host chunk loop with
       |                                    a wall-clock deadline, OR
       |     core.fused_shedder             shed[fused]
       |     (drain_mode="fused")           (``TrustIRConfig.drain_mode``)
       |                                    ONE jitted device step per
       |                                    batch: Pallas shed_partition
       |                                    probe+tier with compacted
       |                                    eval indices, static-shape
       |                                    gather, batched evaluator,
       |                                    scatter, cache/prior
       |                                    fold-back — async-dispatched
       |                                    so batch N+1 forms while
       |                                    batch N computes
    respond  scheduling.scheduler.drain     split per-request Responses;
                                            hedged re-dispatch via
                                            distribution.fault_tolerance

With a multi-replica fleet (``repro.cluster``) the map gains a layer in
FRONT of this one — ``route -> admit -> steal -> drain -> hedge ->
gossip -> join/leave``:

    route    cluster.routing                consistent-hash ring picks
       |                                    the tenant's replica shard
    admit    (this subsystem, per replica)  the ladder above, against
       |                                    THAT replica's regime
    steal    cluster.coordinator            hot bank -> idle sibling,
       |                                    back of the lowest class
       |                                    (EDF heads never reorder)
    drain    cluster.coordinator            round-robin micro-batches
       |                                    across replicas; decode
       |                                    requests only occupy batch
       |                                    budget when a KVCachePool
       |                                    slot is claimable
    hedge    distribution.fault_tolerance   stuck requests race a twin
       |                                    on a REAL backup replica;
       |                                    first completion wins, the
       |                                    loser is deduplicated
       |                                    fleet-wide
    gossip   cluster.gossip                 fresh Trust-DB cache fills
       |                                    broadcast to siblings on a
       |                                    bounded budget (hot URLs
       |                                    evaluated once fleet-wide)
    join/    cluster.coordinator            runtime membership: fence +
    leave                                   drain-and-handoff (EDF
                                            order) on leave, admission-
                                            journal replay on crash,
                                            autoscaler-voted joins and
                                            leaves between min/max
                                            replica bounds

No *admitted* request is ever dropped: every item leaves with a trust
value (paper §5 invariant, preserved across the batching layer), every
rejection is an observable ``Response`` with a reason — never silence —
and fleet-wide each request id yields EXACTLY one ``Response`` even
when its hedged twin also ran.
"""
from repro.scheduling.batcher import (MicroBatch, MicroBatcher,
                                      to_fused_inputs)
from repro.scheduling.priorities import (AdmissionPolicy, Priority,
                                         REASON_QUEUE_FULL,
                                         REASON_RATE_LIMITED,
                                         REASON_SHED_LOW_HEAVY,
                                         REASON_SHED_LOW_VERY_HEAVY,
                                         REASON_SHED_NORMAL_VERY_HEAVY)
from repro.scheduling.queues import (EDFQueue, PriorityQueueBank,
                                     QueuedRequest)
from repro.scheduling.ratelimit import TenantRateLimiter, TokenBucket
from repro.scheduling.scheduler import (Request, Response, Scheduler,
                                        SchedulerConfig, SchedulerStats)

__all__ = [
    "AdmissionPolicy", "Priority",
    "REASON_QUEUE_FULL", "REASON_RATE_LIMITED", "REASON_SHED_LOW_HEAVY",
    "REASON_SHED_LOW_VERY_HEAVY", "REASON_SHED_NORMAL_VERY_HEAVY",
    "EDFQueue", "PriorityQueueBank", "QueuedRequest",
    "TenantRateLimiter", "TokenBucket",
    "MicroBatch", "MicroBatcher", "to_fused_inputs",
    "Request", "Response", "Scheduler", "SchedulerConfig",
    "SchedulerStats",
]
