"""Priority-aware admission & scheduling in front of the Load Shedder.

Request lifecycle (who owns each hop). The front half is the retrieval
stage (``repro.retrieval``, optional — engines fed pre-retrieved
candidate sets start at *arrive*):

    parse    retrieval.text                 tokenize -> common-word
       |                                    filter -> stem the raw
       |                                    query string
    index    retrieval.index / .shard       blocked inverted-index
       |                                    build (per-block postings,
       |                                    sequential merge) held as
       |                                    doc-partitioned IndexShards
       |                                    owned by replicas through
       |                                    the consistent-hash ring
       |                                    (``"docpart:p"`` keys);
       |                                    dense static-shape postings
       |                                    with precomputed BM25
       |                                    per-posting weights,
       |                                    collection-GLOBAL stats so
       |                                    a sharded fleet ranks like
       |                                    one big index
    retrieve ServingEngine.enqueue_query    jitted BM25 segment-sum ->
       |     (retrieval.CorpusSearcher)     Pallas ``topk_select``
       |                                    per shard, scatter-gather
       |                                    merge (score desc, doc id
       |                                    asc) picks the candidate
       |                                    set; measured retrieve
       |                                    latency feeds the
       |                                    LoadMonitor under the
       |                                    WarmupGate rule so
       |                                    Ucapacity reflects the
       |                                    whole pipeline
    scatter  repro.fanout                   quorum-gather[hedged]
       |     (FanoutSearcher)               (``TrustIRConfig.
       |                                    fanout_*``): the fan-out
       |                                    answers at the first-
       |                                    ``quorum_k``-of-n shard
       |                                    completion; late stripes
       |                                    are prior-answered from
       |                                    the stripe answer cache
       |                                    (trust already on file) or
       |                                    the downstream trust
       |                                    prior — never dropped; a
       |                                    straggling shard probe
       |                                    races a twin on a sibling's
       |                                    MIRROR stripes (selective
       |                                    replication of persistently
       |                                    slow shards, EWMA-picked,
       |                                    bounded, dropped on
       |                                    recovery), charged to the
       |                                    same fleet hedge budget;
       |                                    ``quorum_k == n`` is
       |                                    bit-identical to the full
       |                                    gather
       |
    arrive   ServingEngine.enqueue          stamp arrival + SLO deadline
       |
    admit    scheduling.priorities          per-regime priority ladder
       |     scheduling.ratelimit           per-tenant token buckets
       |                                    (reject => explicit Response
       |                                     from the average-trust
       |                                     prior, admitted=False)
    quarantine scheduling.quarantine        per-WORK-SIGNATURE circuit
       |     (PoisonQuarantine)             breaker in front of the
       |                                    ladder: after ``k`` executor
       |                                    errors on batches containing
       |                                    a signature (md5 of the
       |                                    candidate-key prefix), new
       |                                    matching requests are
       |                                    prior-answered
       |                                    (REASON_QUARANTINED) instead
       |                                    of queued — a query of death
       |                                    costs O(k) evaluator crashes
       |                                    per replica, not one per
       |                                    arrival; after
       |                                    ``quarantine_probe_after_s``
       |                                    a HALF-OPEN timed probe
       |                                    admits ONE matching request,
       |                                    and a clean completion
       |                                    closes the breaker (a
       |                                    deployed evaluator fix
       |                                    un-quarantines itself);
       |                                    innocent signatures struck
       |                                    by sharing a failed batch
       |                                    decay back to zero on any
       |                                    clean completion
       |                                    (``TrustIRConfig.
       |                                    quarantine_k`` — 0 disables)
    queue    scheduling.queues              EDF within class, strict
       |                                    priority across classes,
       |                                    static-capacity backpressure
    batch    scheduling.batcher             coalesce queued candidate
       |                                    sets into one padded,
       |                                    budget-shaped micro-batch
    execute  scheduling.executor            ONE DrainExecutor sequences
       |     (DrainExecutor)                every path: a depth-k
       |                                    in-flight window
       |                                    (``TrustIRConfig.
       |                                    pipeline_depth``; depth 1 =
       |                                    sync-per-drain, depth >= 2
       |                                    keeps the window open
       |                                    ACROSS drain calls so batch
       |                                    N+2 forms + transfers while
       |                                    N computes and N+1 waits;
       |                                    with ``TrustIRConfig.
       |                                    adaptive_depth`` a bounded
       |                                    hysteresis controller
       |                                    (cluster.depth) retunes the
       |                                    window each drain tick —
       |                                    deepen under backlog,
       |                                    shallow when queue delay
       |                                    eats the deadline, reading
       |                                    the capacity planner's
       |                                    STAGE_QUEUE p99 when no
       |                                    fresh sample exists — one
       |                                    step at a time between
       |                                    ``adaptive_depth_min`` and
       |                                    the static config, which
       |                                    stays the CLAMP; streak
       |                                    votes + cooldown mean
       |                                    alternating pressure never
       |                                    flaps the depth),
       |                                    per-batch completion
       |                                    callbacks (results, Trust-
       |                                    DB/prior fold-back, Load-
       |                                    Monitor observations land
       |                                    as EACH batch finishes, and
       |                                    ``poll`` folds ready
       |                                    batches without blocking),
       |                                    and exception-mid-window
       |                                    rescue (a failed batch is
       |                                    prior-answered; the rest of
       |                                    the window still lands)
    shed     core.shedder                   ONE three-regime shedding
       |     (drain_mode="host")            decision per micro-batch
       |                                    (EVAL / CACHED / PRIOR tiers)
       |                                    via the host chunk loop with
       |                                    a wall-clock deadline
       |                                    (sequential: the executor
       |                                    runs it eagerly), OR
       |     core.fused_shedder             shed[fused]
       |     (drain_mode="fused")           (``TrustIRConfig.drain_mode``)
       |                                    ONE jitted device step per
       |                                    batch: Pallas shed_partition
       |                                    probe+tier ((8,128)-lane
       |                                    blocks, ragged tails padded
       |                                    in-kernel) with compacted
       |                                    eval indices, static-shape
       |                                    gather, batched evaluator,
       |                                    scatter, cache/prior
       |                                    fold-back — staged (host->
       |                                    device transfer) then
       |                                    dispatched, both async; the
       |                                    Trust-DB probe walks a
       |                                    ways-LEADING cache tile
       |                                    (one (8,128) VMEM block per
       |                                    way instead of a strided
       |                                    row slab); a mesh-sharded
       |                                    evaluator (serving.
       |                                    evaluators.
       |                                    make_sharded_evaluator)
       |                                    hands the engine its
       |                                    ``feature_sharding`` so
       |                                    stage() device_puts each
       |                                    batch's gathered features
       |                                    with the evaluator's INPUT
       |                                    sharding — batch N+2's
       |                                    transfer overlaps the
       |                                    sharded forward of batch N
       |                                    inside the same depth-k
       |                                    window, exactly-once
       |                                    fold-back unchanged
    respond  scheduling.scheduler           split per-request Responses
                                            per completed batch; hedged
                                            re-dispatch via
                                            distribution.fault_tolerance

With a multi-replica fleet (``repro.cluster``) the map gains a layer in
FRONT of this one — ``route -> admit -> steal -> drain -> hedge ->
gossip -> join/leave``:

    route    cluster.routing                consistent-hash ring picks
       |                                    the tenant's replica shard
    admit    (this subsystem, per replica)  the ladder above, against
       |                                    THAT replica's regime
    steal    cluster.coordinator            hot bank -> idle sibling,
       |                                    non-head entry of the
       |                                    lowest class picked by
       |                                    estimated eval cost (items
       |                                    x Trust-DB miss probability
       |                                    — cache-cold work migrates,
       |                                    cache-hot work stays warm;
       |                                    EDF heads never reorder)
    drain    cluster.coordinator            round-robin micro-batches
       |                                    across replicas, one
       |                                    DrainExecutor window per
       |                                    replica spanning rounds
       |                                    (device steps overlap the
       |                                    next round's scans); each
       |                                    round POLLS completed
       |                                    batches first so steal/
       |                                    hedge/autoscale read fresh
       |                                    stats, not one batch late;
       |                                    decode requests only occupy
       |                                    batch budget when a
       |                                    KVCachePool slot is
       |                                    claimable
    hedge    distribution.fault_tolerance   stuck requests race a twin
       |                                    on a REAL backup replica;
       |                                    first completion wins, the
       |                                    loser is deduplicated
       |                                    fleet-wide
    gossip   cluster.gossip                 fresh Trust-DB cache fills
       |                                    reach siblings on a bounded
       |                                    budget (hot URLs evaluated
       |                                    once fleet-wide) — either
       |                                    O(n^2) broadcast (default)
       |                                    or epidemic peer-sampling
       |                                    push (O(log n) fanout per
       |                                    delta, relayed) + one
       |                                    anti-entropy digest pull per
       |                                    round, O(n log n) messages
       |                                    total (``TrustIRConfig.
       |                                    gossip_mode``)
    forecast cluster.capacity               feedforward autoscaling:
       |                                    sliding-window NHPP rate
       |                                    estimate of the arrival
       |                                    curve, extrapolated
       |                                    warmup_lead_s ahead and
       |                                    folded into the SAME
       |                                    watermark membership vote
       |                                    (shared cooldown) so
       |                                    scale-up fires BEFORE the
       |                                    queue-pressure breach; the
       |                                    per-stage ServiceTimeModel
       |                                    it fits from live drain
       |                                    stats also answers what-if
       |                                    predict(n, depth, batch) ->
       |                                    (throughput, p99)
    prewarm  cluster.replica                forecast-triggered joins
       |                                    jit-compile the micro-batch
       |                                    shape on synthetic keys
       |                                    BEFORE the ring unfences
       |                                    the new replica — its first
       |                                    real batch is never cold
       |                                    (cache/prior/clock snapshot
       |                                    -restored around the warm
       |                                    pass, so no serving state
       |                                    leaks from prewarm traffic)
    restart  cluster.coordinator            coordinated rolling
       |                                    restarts: ring-disjoint
       |                                    waves (no replica restarts
       |                                    alongside the sibling that
       |                                    would inherit its keys),
       |                                    fence + queue handoff +
       |                                    warm-cache export per wave,
       |                                    autoscaler membership votes
       |                                    held for the sweep, restart
       |                                    counters banked so fleet
       |                                    stats survive the engine
       |                                    rebuild
    join/    cluster.coordinator            runtime membership: fence +
    leave                                   drain-and-handoff (EDF
                                            order) on leave — queued
                                            work, the top-K freshest
                                            Trust-DB entries (warm
                                            handoff via the gossip
                                            apply_trust_deltas path),
                                            AND the doc-partition index
                                            stripes remap_diff claims
                                            (postings travel
                                            export_docs -> absorb; a
                                            crash rebuilds them from
                                            the corpus on survivors) —
                                            admission-journal replay on
                                            crash, autoscaler-voted
                                            joins and leaves between
                                            min/max replica bounds

No *admitted* request is ever dropped: every item leaves with a trust
value (paper §5 invariant, preserved across the batching layer), every
rejection is an observable ``Response`` with a reason — never silence —
and fleet-wide each request id yields EXACTLY one ``Response`` even
when its hedged twin also ran.
"""
from repro.scheduling.batcher import (MicroBatch, MicroBatcher,
                                      to_fused_inputs)
from repro.scheduling.executor import DrainExecutor
from repro.scheduling.priorities import (AdmissionPolicy, Priority,
                                         REASON_QUARANTINED,
                                         REASON_QUEUE_FULL,
                                         REASON_RATE_LIMITED,
                                         REASON_SHED_LOW_HEAVY,
                                         REASON_SHED_LOW_VERY_HEAVY,
                                         REASON_SHED_NORMAL_VERY_HEAVY)
from repro.scheduling.quarantine import (PoisonQuarantine,
                                         QuarantineStats,
                                         work_signature)
from repro.scheduling.queues import (EDFQueue, PriorityQueueBank,
                                     QueuedRequest)
from repro.scheduling.ratelimit import TenantRateLimiter, TokenBucket
from repro.scheduling.scheduler import (Request, Response, Scheduler,
                                        SchedulerConfig, SchedulerStats)

__all__ = [
    "AdmissionPolicy", "Priority",
    "REASON_QUARANTINED", "REASON_QUEUE_FULL", "REASON_RATE_LIMITED",
    "REASON_SHED_LOW_HEAVY", "REASON_SHED_LOW_VERY_HEAVY",
    "REASON_SHED_NORMAL_VERY_HEAVY",
    "EDFQueue", "PriorityQueueBank", "QueuedRequest",
    "TenantRateLimiter", "TokenBucket",
    "DrainExecutor",
    "MicroBatch", "MicroBatcher", "to_fused_inputs",
    "PoisonQuarantine", "QuarantineStats", "work_signature",
    "Request", "Response", "Scheduler", "SchedulerConfig",
    "SchedulerStats",
]
