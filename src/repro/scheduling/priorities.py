"""Request priority classes and per-regime admission rules.

The Load Shedder decides *what to evaluate* inside an admitted batch
(paper §5); this module decides *which requests get batch capacity at
all* when the offered load exceeds it — the admission layer that
tail-tolerant search stacks (1707.07426) and vertical-search capacity
planning (1006.5059) put in front of the shedding logic.

Four classes, mirroring the spirit of the shedder's three regimes:

=============  =========================================================
``CRITICAL``   interactive / paid traffic; always admitted, bypasses
               the tenant rate limiter, drained first.
``HIGH``       latency-sensitive; admitted in every regime (subject to
               rate limits and queue backpressure).
``NORMAL``     default; throttled only under VERY_HEAVY pressure.
``LOW``        batch / prefetch / crawler refresh; throttled under
               HEAVY pressure, rejected outright under VERY_HEAVY.
=============  =========================================================

Rejection is never a silent drop: the scheduler answers every rejected
request with an explicit ``Response`` carrying the average-trust prior
(the same fallback tier the shedder uses past the deadline), flagged
``admitted=False`` with a machine-readable ``reason``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Optional

from repro.core.regimes import Regime


class Priority(enum.IntEnum):
    """Lower value = more important (sorts first in queue order)."""
    CRITICAL = 0
    HIGH = 1
    NORMAL = 2
    LOW = 3


# Machine-readable rejection reasons (Response.reason).
REASON_RATE_LIMITED = "rate_limited"          # tenant token bucket empty
REASON_SHED_LOW_HEAVY = "shed_low_heavy"      # LOW over watermark, HEAVY
REASON_SHED_LOW_VERY_HEAVY = "shed_low_very_heavy"
REASON_SHED_NORMAL_VERY_HEAVY = "shed_normal_very_heavy"
REASON_QUEUE_FULL = "queue_full"              # static-capacity backpressure
REASON_QUARANTINED = "quarantined"            # poison-pill circuit breaker open


@dataclass(frozen=True)
class AdmissionPolicy:
    """Per-regime admission ladder (regime from the *offered* load:
    queued items + the incoming request's candidate count).

    ``low_watermark`` / ``normal_watermark`` are queue-fill fractions
    (0..1) above which the respective class stops being admitted in the
    regime that throttles it.
    """
    low_watermark: float = 0.5      # LOW fill bound under HEAVY
    normal_watermark: float = 0.9   # NORMAL fill bound under VERY_HEAVY

    def decide(self, priority: Priority, regime: Regime,
               fill_frac: float) -> Optional[str]:
        """Return ``None`` to admit, or a rejection reason string.

        fill_frac: current fill of the *target class queue* (0..1).
        Tenant rate limiting and queue backpressure are the scheduler's
        own checks, applied after this ladder (CRITICAL bypasses the
        rate limiter there).
        """
        if priority is Priority.CRITICAL:
            return None
        if regime is Regime.NORMAL:
            return None
        if priority is Priority.LOW:
            if regime is Regime.VERY_HEAVY:
                return REASON_SHED_LOW_VERY_HEAVY
            if fill_frac >= self.low_watermark:
                return REASON_SHED_LOW_HEAVY
            return None
        if (priority is Priority.NORMAL and regime is Regime.VERY_HEAVY
                and fill_frac >= self.normal_watermark):
            return REASON_SHED_NORMAL_VERY_HEAVY
        return None
