"""Earliest-deadline-first queues with static-capacity backpressure.

One EDF heap per priority class. Within a class the request whose
*absolute deadline* (arrival + SLO) expires soonest is drained first —
the ordering that minimizes deadline misses for a work-conserving
server; across classes drain order is strict priority (CRITICAL before
HIGH before NORMAL before LOW).

Capacity is static (requests per class). ``push`` returns ``False``
when the class queue is full — callers turn that into an explicit
``queue_full`` rejection response, never a silent drop.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from repro.scheduling.priorities import Priority


@dataclass
class QueuedRequest:
    """A request waiting for batch capacity.

    ``request`` is the engine-level ``Request`` (items + features);
    ``deadline_t`` is absolute (arrival + SLO) — the EDF key.
    """
    request: Any
    priority: Priority
    tenant: str
    deadline_t: float
    enqueue_t: float
    hedged: bool = False
    n_hedges: int = 0         # times this request has been re-dispatched
    last_hedge_t: Optional[float] = None    # when the last twin launched

    @property
    def hedge_wait_base_t(self) -> float:
        """Re-hedges wait a full hedge interval since the LAST dispatch,
        not since enqueue (else every scan past the threshold fires)."""
        return (self.enqueue_t if self.last_hedge_t is None
                else self.last_hedge_t)

    def dispatch_twin(self, crit_push, fire_t: float) -> bool:
        """Escalate a hedge copy of this request via ``crit_push`` (a
        CRITICAL queue's ``push``); on success mark THIS entry hedged
        and stamp the dispatch time. Shared by engine-internal and
        cluster hedging so the twin bookkeeping cannot diverge."""
        twin = QueuedRequest(
            request=self.request, priority=self.priority,
            tenant=self.tenant, deadline_t=self.deadline_t,
            enqueue_t=self.enqueue_t, hedged=True,
            n_hedges=self.n_hedges + 1, last_hedge_t=fire_t)
        if not crit_push(twin):
            return False
        self.hedged = True
        self.n_hedges += 1
        self.last_hedge_t = fire_t
        return True

    @property
    def n_items(self) -> int:
        return int(len(self.request.item_keys))


class EDFQueue:
    """Bounded min-heap keyed by absolute deadline (FIFO tie-break)."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._heap: List[Tuple[float, int, QueuedRequest]] = []
        self._seq = itertools.count()
        self.n_items = 0          # queued candidate items (load estimate)

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, qreq: QueuedRequest) -> bool:
        if len(self._heap) >= self.capacity:
            return False
        heapq.heappush(self._heap,
                       (qreq.deadline_t, next(self._seq), qreq))
        self.n_items += qreq.n_items
        return True

    def pop(self) -> Optional[QueuedRequest]:
        if not self._heap:
            return None
        _, _, qreq = heapq.heappop(self._heap)
        self.n_items -= qreq.n_items
        return qreq

    def peek(self) -> Optional[QueuedRequest]:
        return self._heap[0][2] if self._heap else None

    def pop_back(self, cost_fn: Optional[Callable] = None,
                 max_candidates: int = 8) -> Optional[QueuedRequest]:
        """Remove a non-head entry for a thief — the work-stealing
        primitive. Never touches the head, so the victim's EDF drain
        order is unchanged for every request that remains.

        Without ``cost_fn``: the entry with the LATEST deadline leaves
        (with >= 2 entries the max-key entry is never the min-key
        head). With ``cost_fn(qreq) -> float`` (cost-aware stealing):
        the HIGHEST-cost entry among the ``max_candidates`` LATEST-
        deadline non-head entries leaves — stealing a cache-cold
        request moves real work to the idle sibling, where stealing a
        cache-hot one would displace cold work only to re-evaluate warm
        items on a cold cache. Scoring is bounded to the back region
        because each cost probe may be a device lookup; deadline breaks
        ties (latest first), so ``cost_fn=None`` and a constant cost_fn
        pick identically.
        """
        if not self._heap:
            return None
        if cost_fn is None:
            i = max(range(len(self._heap)),
                    key=lambda j: self._heap[j][:2])
        else:
            head_j = min(range(len(self._heap)),
                         key=lambda j: self._heap[j][:2]) \
                if len(self._heap) > 1 else None
            back = heapq.nlargest(
                max(max_candidates, 1),
                (j for j in range(len(self._heap)) if j != head_j),
                key=lambda j: self._heap[j][:2])
            i = max(back, key=lambda j: (cost_fn(self._heap[j][2]),) +
                    self._heap[j][:2])
        _, _, qreq = self._heap[i]
        last = self._heap.pop()
        if i < len(self._heap):
            self._heap[i] = last
            heapq.heapify(self._heap)
        self.n_items -= qreq.n_items
        return qreq

    def fill_frac(self) -> float:
        return len(self._heap) / max(self.capacity, 1)

    def entries(self) -> Iterator[QueuedRequest]:
        """Heap-order iteration (NOT sorted); for scans, not draining."""
        return (q for _, _, q in self._heap)


class PriorityQueueBank:
    """Strict-priority bank of per-class EDF queues."""

    def __init__(self, capacity_per_class: int):
        self.queues: Dict[Priority, EDFQueue] = {
            p: EDFQueue(capacity_per_class) for p in Priority}

    def __len__(self) -> int:
        return sum(len(q) for q in self.queues.values())

    @property
    def n_items(self) -> int:
        return sum(q.n_items for q in self.queues.values())

    def push(self, qreq: QueuedRequest) -> bool:
        return self.queues[qreq.priority].push(qreq)

    def pop_next(self) -> Optional[QueuedRequest]:
        """Highest-priority class first; EDF within the class."""
        for p in Priority:
            q = self.queues[p].pop()
            if q is not None:
                return q
        return None

    def peek_next(self) -> Optional[QueuedRequest]:
        for p in Priority:
            head = self.queues[p].peek()
            if head is not None:
                return head
        return None

    def fill_frac(self, priority: Priority) -> float:
        return self.queues[priority].fill_frac()

    def steal_back(self, min_leave: int = 1,
                   cost_fn: Optional[Callable] = None
                   ) -> Optional[QueuedRequest]:
        """Pop from the back of the lowest-importance non-empty class.

        Victim-side work stealing: least-important work leaves first,
        and a class is only robbed while more than ``min_leave``
        entries remain — with the default of 1 the head of every class
        stays in place, so the victim's EDF drain order is never
        reordered by a steal. Within the robbed class, ``cost_fn``
        (estimated evaluation cost, e.g. items x Trust-DB miss
        probability on the victim) selects WHICH non-head entry leaves
        — cache-cold work migrates, cache-hot work stays where its
        cache is warm; without it the latest-deadline back entry leaves
        (the original policy, and the tie-break either way).

        The CRITICAL queue is never robbed: it is next to drain here
        anyway, and it may hold escalated hedge twins (entries whose
        ``priority`` is their ORIGINAL class) — a thief re-pushing one
        via ``push`` would silently demote it out of escalation.
        """
        for p in reversed(list(Priority)):
            if p is Priority.CRITICAL:
                continue
            q = self.queues[p]
            if len(q) > min_leave:
                return q.pop_back(cost_fn=cost_fn)
        return None
