"""Earliest-deadline-first queues with static-capacity backpressure.

One EDF heap per priority class. Within a class the request whose
*absolute deadline* (arrival + SLO) expires soonest is drained first —
the ordering that minimizes deadline misses for a work-conserving
server; across classes drain order is strict priority (CRITICAL before
HIGH before NORMAL before LOW).

Capacity is static (requests per class). ``push`` returns ``False``
when the class queue is full — callers turn that into an explicit
``queue_full`` rejection response, never a silent drop.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.scheduling.priorities import Priority


@dataclass
class QueuedRequest:
    """A request waiting for batch capacity.

    ``request`` is the engine-level ``Request`` (items + features);
    ``deadline_t`` is absolute (arrival + SLO) — the EDF key.
    """
    request: Any
    priority: Priority
    tenant: str
    deadline_t: float
    enqueue_t: float
    hedged: bool = False

    @property
    def n_items(self) -> int:
        return int(len(self.request.item_keys))


class EDFQueue:
    """Bounded min-heap keyed by absolute deadline (FIFO tie-break)."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._heap: List[Tuple[float, int, QueuedRequest]] = []
        self._seq = itertools.count()
        self.n_items = 0          # queued candidate items (load estimate)

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, qreq: QueuedRequest) -> bool:
        if len(self._heap) >= self.capacity:
            return False
        heapq.heappush(self._heap,
                       (qreq.deadline_t, next(self._seq), qreq))
        self.n_items += qreq.n_items
        return True

    def pop(self) -> Optional[QueuedRequest]:
        if not self._heap:
            return None
        _, _, qreq = heapq.heappop(self._heap)
        self.n_items -= qreq.n_items
        return qreq

    def peek(self) -> Optional[QueuedRequest]:
        return self._heap[0][2] if self._heap else None

    def fill_frac(self) -> float:
        return len(self._heap) / max(self.capacity, 1)

    def entries(self) -> Iterator[QueuedRequest]:
        """Heap-order iteration (NOT sorted); for scans, not draining."""
        return (q for _, _, q in self._heap)


class PriorityQueueBank:
    """Strict-priority bank of per-class EDF queues."""

    def __init__(self, capacity_per_class: int):
        self.queues: Dict[Priority, EDFQueue] = {
            p: EDFQueue(capacity_per_class) for p in Priority}

    def __len__(self) -> int:
        return sum(len(q) for q in self.queues.values())

    @property
    def n_items(self) -> int:
        return sum(q.n_items for q in self.queues.values())

    def push(self, qreq: QueuedRequest) -> bool:
        return self.queues[qreq.priority].push(qreq)

    def pop_next(self) -> Optional[QueuedRequest]:
        """Highest-priority class first; EDF within the class."""
        for p in Priority:
            q = self.queues[p].pop()
            if q is not None:
                return q
        return None

    def peek_next(self) -> Optional[QueuedRequest]:
        for p in Priority:
            head = self.queues[p].peek()
            if head is not None:
                return head
        return None

    def fill_frac(self, priority: Priority) -> float:
        return self.queues[priority].fill_frac()
