"""Poison-pill detector + quarantine: a circuit breaker per work signature.

A *query of death* is a request whose candidate features make the
evaluator raise (or hang until a watchdog kills it). Without defence,
every retry re-poisons the ``DrainExecutor`` window: the failed batch is
prior-answered (the no-drop invariant holds), but the executor error
count grows without bound and every batch sharing the window with the
poison pays the rescue path — the classic query-of-death outage mode of
production retrieval stacks (tail-tolerant search, 1707.07426, survives
*slow* shards; this module survives *toxic* work).

The defence is signature-keyed:

``work_signature(item_keys)``
    A stable content hash of the request's candidate-set prefix. A
    query of death retrieves the same candidate documents every time it
    is asked, so its requests collapse onto ONE signature no matter
    which tenant or replica carries them — while organic traffic
    spreads across signatures (hot-URL repeats share one signature too,
    which is harmless: signatures only matter once they strike).

``PoisonQuarantine``
    Per-signature circuit breaker in front of the evaluator:

    * CLOSED   — requests flow; each executor error carrying the
      signature is a strike.
    * OPEN     — after ``k`` strikes. Matching requests are
      prior-answered at admission (an explicit ``Response`` with reason
      ``"quarantined"`` — never a silent drop) and the evaluator never
      sees them, capping executor errors at O(k) per signature.
    * HALF_OPEN — ``probe_after_s`` after opening, exactly ONE matching
      request is admitted as a probe. Success closes the breaker
      (strikes reset); failure re-opens it for another
      ``probe_after_s``.

The breaker never touches requests already queued when it opens — they
were admitted under a closed breaker and drain normally (their errors
still count, so the O(k) bound is ``k`` strikes plus the in-queue
stragglers at opening time plus one per probe).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional

import numpy as np

# Breaker states.
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"

# How many leading candidate keys feed the signature. A prefix keeps
# the hash O(1) per request; 64 keys is far past collision range for
# organic traffic while a query of death (identical candidate set)
# always collides with itself.
SIGNATURE_PREFIX = 64


def work_signature(item_keys) -> str:
    """Stable content hash of a candidate-set prefix (hex, 12 chars)."""
    keys = np.asarray(item_keys, dtype=np.uint32)[:SIGNATURE_PREFIX]
    return hashlib.md5(keys.tobytes()).hexdigest()[:12]


@dataclass
class _Breaker:
    state: str = CLOSED
    strikes: int = 0            # errors while CLOSED/HALF_OPEN (resets on close)
    opened_t: float = 0.0       # clock time of the last open transition
    n_errors: int = 0           # lifetime executor errors for this signature
    n_blocked: int = 0          # requests prior-answered by this breaker
    n_probes: int = 0           # half-open probes admitted


@dataclass
class QuarantineStats:
    n_blocked: int = 0          # requests prior-answered across signatures
    n_strikes: int = 0          # executor errors recorded against breakers
    n_opens: int = 0            # CLOSED/HALF_OPEN -> OPEN transitions
    n_probes: int = 0           # half-open probes admitted
    n_recoveries: int = 0       # probes that closed a breaker

    def as_dict(self) -> Dict[str, int]:
        return dict(self.__dict__)


class PoisonQuarantine:
    """Signature-keyed circuit breakers (see module docstring).

    ``now`` is a zero-arg clock callable — the scheduler passes its own
    (simulated or wall) clock so half-open timing is deterministic in
    simulation.
    """

    def __init__(self, k: int, probe_after_s: float, now) -> None:
        if k <= 0:
            raise ValueError("quarantine k must be positive")
        if probe_after_s <= 0:
            raise ValueError("probe_after_s must be positive")
        self.k = int(k)
        self.probe_after_s = float(probe_after_s)
        self._now = now
        self._breakers: Dict[str, _Breaker] = {}
        self.stats = QuarantineStats()

    # -- admission-time check ------------------------------------------------

    def check(self, sig: str) -> bool:
        """True = admit the request; False = prior-answer it.

        Called on the scheduler's submit path. An OPEN breaker past its
        probe timer admits exactly one request as the half-open probe.
        """
        br = self._breakers.get(sig)
        if br is None or br.state == CLOSED:
            return True
        if br.state == OPEN and (self._now() - br.opened_t
                                 >= self.probe_after_s):
            br.state = HALF_OPEN
            br.n_probes += 1
            self.stats.n_probes += 1
            return True
        # OPEN inside the timer, or HALF_OPEN with the probe already out.
        br.n_blocked += 1
        self.stats.n_blocked += 1
        return False

    # -- executor feedback ---------------------------------------------------

    def record_failure(self, sig: str) -> None:
        """An executor error carried this signature: one strike."""
        br = self._breakers.setdefault(sig, _Breaker())
        br.n_errors += 1
        self.stats.n_strikes += 1
        if br.state == HALF_OPEN:
            # The probe failed: straight back to OPEN, timer restarted.
            br.state = OPEN
            br.opened_t = self._now()
            self.stats.n_opens += 1
            return
        if br.state == CLOSED:
            br.strikes += 1
            if br.strikes >= self.k:
                br.state = OPEN
                br.opened_t = self._now()
                self.stats.n_opens += 1

    def record_success(self, sig: str) -> None:
        """A batch carrying this signature completed cleanly."""
        br = self._breakers.get(sig)
        if br is None:
            return
        if br.state == HALF_OPEN:
            self.stats.n_recoveries += 1
        if br.state != OPEN:
            # HALF_OPEN probe success closes; CLOSED strikes decay to
            # zero (a signature that evaluates cleanly is not poison).
            br.state = CLOSED
            br.strikes = 0

    # -- restart banking -----------------------------------------------------

    def adopt(self, other: "PoisonQuarantine") -> None:
        """Inherit ``other``'s breaker state and lifetime stats.

        A rolling restart rebuilds the serving engine — and with it the
        scheduler's quarantine — from scratch.  Without banking, every
        OPEN breaker is forgotten and the restarted replica re-eats
        ``k`` poison strikes per known-bad signature, wave after wave.
        ``ReplicaHandle.restart`` calls this right after the rebuild
        (next to the scheduler-counter banking) so breakers ride
        through.  The replica clock is monotone across a restart (the
        new engine's clock resumes at ``now + downtime``), so inherited
        ``opened_t`` values keep their meaning for half-open timing.
        """
        self._breakers = other._breakers
        self.stats = other.stats

    # -- introspection -------------------------------------------------------

    @property
    def any_tracked(self) -> bool:
        return bool(self._breakers)

    def state_of(self, sig: str) -> str:
        br = self._breakers.get(sig)
        return br.state if br is not None else CLOSED

    def per_signature(self) -> Dict[str, Dict[str, object]]:
        return {sig: {"state": br.state, "strikes": br.strikes,
                      "n_errors": br.n_errors, "n_blocked": br.n_blocked,
                      "n_probes": br.n_probes}
                for sig, br in self._breakers.items()}

    def max_errors_per_signature(self) -> int:
        return max((br.n_errors for br in self._breakers.values()),
                   default=0)
