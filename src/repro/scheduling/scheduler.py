"""The scheduling event loop: admit -> enqueue -> drain micro-batches.

Ties the subsystem together in front of the Load Shedder:

  1. **Admit** (``submit``): classify the *offered* load (queued items +
     incoming candidates) into the paper's three regimes and apply the
     per-regime priority ladder (``priorities.AdmissionPolicy``) plus
     per-tenant token buckets (``ratelimit``). Rejections return an
     explicit ``Response`` answered from the average-trust prior —
     ``admitted=False``, machine-readable ``reason`` — never a silent
     drop.
  2. **Enqueue**: admitted requests enter per-priority EDF queues with
     static-capacity backpressure (``queues``).
  3. **Drain** (``drain``): the batcher coalesces queued requests into
     padded, budget-shaped micro-batches (``batcher``) and each batch
     goes through the :class:`~repro.scheduling.executor.DrainExecutor`
     — a depth-k in-flight window over the shedder (host chunk loop or
     fused device step) that finalizes each batch as it lands, splits
     per-request responses, and rescues a batch whose executor raised
     by answering it from the average-trust prior. Requests that have
     waited past the hedge latency are re-dispatched at CRITICAL
     priority via ``distribution.fault_tolerance.HedgedDispatch``
     (first completion wins, twin is deduplicated).

The paper's no-drop invariant survives end to end: every *admitted*
request leaves ``drain`` with a trust value per item (property-tested
under all three regimes in ``tests/test_scheduling.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import TrustIRConfig
from repro.core.regimes import Regime, classify
from repro.core.shedder import (LoadShedder, ShedResult, TIER_CACHED,
                                TIER_EVAL, TIER_PRIOR)
from repro.distribution.fault_tolerance import HedgedDispatch
from repro.scheduling.batcher import MicroBatch, MicroBatcher
from repro.scheduling.executor import DrainExecutor
from repro.scheduling.priorities import (AdmissionPolicy, Priority,
                                         REASON_QUARANTINED,
                                         REASON_QUEUE_FULL,
                                         REASON_RATE_LIMITED)
from repro.scheduling.quarantine import PoisonQuarantine, work_signature
from repro.scheduling.queues import PriorityQueueBank, QueuedRequest
from repro.scheduling.ratelimit import TenantRateLimiter


@dataclass
class Request:
    request_id: int
    item_keys: np.ndarray
    buckets: np.ndarray
    features: Dict[str, np.ndarray]
    arrival_s: float
    slo_s: float
    # LM decode requests must claim a KVCachePool slot to make progress;
    # the batcher keeps them queued while no slot is claimable instead of
    # spending batch budget they cannot use.
    needs_kv_slot: bool = False


@dataclass
class Response:
    request_id: int
    trust: np.ndarray
    tier: np.ndarray
    latency_s: float
    met_slo: bool
    shed: ShedResult
    priority: Priority = Priority.NORMAL
    admitted: bool = True
    reason: str = ""                 # rejection reason when not admitted
    queue_delay_s: float = 0.0
    hedged: bool = False


@dataclass
class SchedulerConfig:
    # Items per micro-batch; 0 derives Ucapacity + Uthreshold rounded up
    # to the evaluator chunk size (the budget `shed_plan` shapes to).
    max_batch_items: int = 0
    queue_capacity_requests: int = 1024      # per priority class
    low_watermark: float = 0.5
    normal_watermark: float = 0.9
    tenant_rate_items_per_s: float = float("inf")
    tenant_burst_items: float = float("inf")
    hedge_after_s: float = 0.0               # 0 disables hedging


def _round_up(n: int, mult: int) -> int:
    return -(-n // max(mult, 1)) * max(mult, 1)


@dataclass
class SchedulerStats:
    n_submitted: int = 0
    n_admitted: int = 0
    n_rejected: int = 0
    rejected_by_reason: Dict[str, int] = field(default_factory=dict)
    n_batches: int = 0
    n_batched_items: int = 0
    n_hedges: int = 0
    n_executor_errors: int = 0      # batches rescued from the prior
    n_quarantined: int = 0          # requests blocked by an open breaker

    def as_dict(self) -> Dict:
        return {"n_submitted": self.n_submitted,
                "n_admitted": self.n_admitted,
                "n_rejected": self.n_rejected,
                "rejected_by_reason": dict(self.rejected_by_reason),
                "n_batches": self.n_batches,
                "n_batched_items": self.n_batched_items,
                "n_hedges": self.n_hedges,
                "n_executor_errors": self.n_executor_errors,
                "n_quarantined": self.n_quarantined,
                "mean_batch_fill": (self.n_batched_items
                                    / max(self.n_batches, 1))}


class Scheduler:
    """Priority-aware admission + EDF queueing + micro-batched shedding.

    ``now`` is the clock (``time.monotonic`` or a ``SimClock.now``
    bound method) — shared with the shedder so queue delays and shed
    response times add up on one timeline.
    """

    def __init__(self, cfg: TrustIRConfig, shedder: LoadShedder,
                 sched_cfg: Optional[SchedulerConfig] = None,
                 now: Optional[Callable[[], float]] = None,
                 kv_pool=None):
        self.cfg = cfg
        # KVCachePool (or bare SlotAllocator) consulted by drain so
        # decode requests without a claimable slot stay queued.
        self.kv_pool = kv_pool
        self.sched_cfg = sched_cfg or SchedulerConfig()
        self._now = now or shedder._now
        self.policy = AdmissionPolicy(
            low_watermark=self.sched_cfg.low_watermark,
            normal_watermark=self.sched_cfg.normal_watermark)
        self.bank = PriorityQueueBank(
            self.sched_cfg.queue_capacity_requests)
        self.limiter = TenantRateLimiter(
            self.sched_cfg.tenant_rate_items_per_s,
            self.sched_cfg.tenant_burst_items)
        self.max_batch_items = self.sched_cfg.max_batch_items or \
            _round_up(cfg.u_capacity + cfg.u_threshold, cfg.chunk_size)
        self.batcher = MicroBatcher(self.max_batch_items)
        self.hedge = (HedgedDispatch(self.sched_cfg.hedge_after_s)
                      if self.sched_cfg.hedge_after_s > 0 else None)
        self.stats = SchedulerStats()
        self._answered: set = set()   # rids whose hedged twin is queued
        # Poison-pill circuit breakers in front of the evaluator
        # (quarantine.PoisonQuarantine): quarantine_k = 0 disables and
        # keeps the pre-chaos submit path untouched.
        qk = getattr(cfg, "quarantine_k", 0)
        self.quarantine = (
            PoisonQuarantine(qk,
                             getattr(cfg, "quarantine_probe_after_s", 2.0),
                             self._now)
            if qk > 0 else None)
        # ONE execution pipeline for every drain path (host chunk loop,
        # fused device step, cluster round-robin): the executor owns
        # the depth-k in-flight window, per-batch completion, and
        # exception-mid-window rescue.
        self.executor = DrainExecutor(
            shedder, self._split_responses,
            depth=getattr(cfg, "pipeline_depth", 1),
            rescue=self._rescue_responses,
            on_error=(self._note_executor_error
                      if self.quarantine is not None else None))
        # Adaptive pipeline depth (cluster.depth): None when disabled —
        # the static-depth drain is then untouched. The coordinator
        # points ``depth_controller.model`` at the fleet's
        # ServiceTimeModel so the latency signal reads the same
        # per-stage fits the capacity planner maintains; standalone the
        # controller runs on the scheduler's own queue-delay EWMA.
        from repro.cluster.depth import controller_from_config
        self.depth_controller = controller_from_config(cfg)
        self._queue_delay_ewma: Optional[float] = None

    # The executor runs whatever shedder the scheduler carries; keeping
    # the reference in ONE place lets baseline drivers swap shedders
    # (``engine.shedder = ProcessAll(...)``) without the pipeline and
    # the admission layer diverging.
    @property
    def shedder(self) -> LoadShedder:
        return self.executor.shedder

    @shedder.setter
    def shedder(self, s: LoadShedder) -> None:
        self.executor.shedder = s

    # -- admission ----------------------------------------------------------
    @property
    def queued_items(self) -> int:
        return self.bank.n_items

    def offered_regime(self, incoming_items: int = 0) -> Regime:
        ucap, uthr = self.shedder.monitor.parameters()
        return classify(self.bank.n_items + incoming_items, ucap, uthr)

    def submit(self, request: Request,
               priority: Priority = Priority.NORMAL,
               tenant: str = "default") -> Optional[Response]:
        """Admit or reject ``request``. Returns ``None`` when the request
        was queued, or the explicit rejection ``Response`` otherwise."""
        self.stats.n_submitted += 1
        now = self._now()
        n = len(request.item_keys)
        regime = self.offered_regime(n)
        reason = None
        # Poison quarantine runs FIRST (even CRITICAL traffic: a query
        # of death is toxic regardless of who asks) — but only once a
        # breaker exists, so un-struck traffic never pays the hash.
        if self.quarantine is not None and self.quarantine.any_tracked \
                and not self.quarantine.check(
                    work_signature(request.item_keys)):
            reason = REASON_QUARANTINED
            self.stats.n_quarantined += 1
        if reason is None:
            reason = self.policy.decide(priority, regime,
                                        self.bank.fill_frac(priority))
        if reason is None and \
                len(self.bank.queues[priority]) >= \
                self.bank.queues[priority].capacity:
            reason = REASON_QUEUE_FULL
        if reason is None and priority is not Priority.CRITICAL \
                and not self.limiter.allow(tenant, n, now):
            # Checked last (after the shed ladder AND backpressure) so
            # tokens are only consumed by requests that actually enter
            # the queue.
            reason = REASON_RATE_LIMITED
        if reason is None:
            qreq = QueuedRequest(request=request, priority=priority,
                                 tenant=tenant,
                                 deadline_t=request.arrival_s
                                 + request.slo_s,
                                 enqueue_t=now)
            admitted = self.bank.push(qreq)
            assert admitted          # capacity checked above
            self.stats.n_admitted += 1
            if self.hedge is not None:
                self.hedge.note_request()   # earn hedge budget
            return None
        self.stats.n_rejected += 1
        self.stats.rejected_by_reason[reason] = \
            self.stats.rejected_by_reason.get(reason, 0) + 1
        return self._reject(request, priority, regime, reason)

    def _prior_answer(self, request: Request, regime: Regime
                      ) -> tuple:
        """Answer a whole request from the average-trust prior (the
        shedder's own fallback tier): the shared construction behind
        explicit rejections AND executor-error rescues, so the two
        degraded paths can never diverge. Returns (trust, tier, shed,
        latency, met_slo) as of now."""
        n = len(request.item_keys)
        means = np.asarray(self.shedder.prior["mean"])
        trust = means[np.asarray(request.buckets) % len(means)
                      ].astype(np.float32)
        tier = np.full((n,), TIER_PRIOR, np.int32)
        shed = ShedResult(trust=trust, tier=tier, regime=regime,
                          response_time_s=0.0, deadline_eff_s=0.0,
                          n_evaluated=0, n_cached=0, n_prior=n, uload=n)
        latency = max(self._now() - request.arrival_s, 0.0)
        return trust, tier, shed, latency, \
            latency <= request.slo_s + 1e-9

    def _reject(self, request: Request, priority: Priority,
                regime: Regime, reason: str) -> Response:
        """Explicit rejection: answered from the average-trust prior,
        so even shed traffic leaves with a trust value per item."""
        trust, tier, shed, latency, met = self._prior_answer(request,
                                                             regime)
        return Response(request_id=request.request_id, trust=trust,
                        tier=tier, latency_s=latency, met_slo=met,
                        shed=shed, priority=priority, admitted=False,
                        reason=reason)

    # -- hedging ------------------------------------------------------------
    def _hedge_scan(self) -> None:
        """Re-dispatch long-waiting non-CRITICAL requests at CRITICAL
        priority (first completion wins; twin deduplicated in
        ``_execute``). Bounded by the hedge budget: ``max_hedges``
        re-issues per request, token-bucket capped as a fraction of
        admitted traffic."""
        if self.hedge.budget_available < 1.0:
            return          # tokens only refill on submit, not mid-scan
        now = self._now()
        crit = self.bank.queues[Priority.CRITICAL]
        for p in (Priority.HIGH, Priority.NORMAL, Priority.LOW):
            for qreq in self.bank.queues[p].entries():
                # The twin goes straight into the CRITICAL queue but
                # keeps its original priority for response accounting.
                if self.hedge.should_hedge(now - qreq.hedge_wait_base_t,
                                           qreq.n_hedges) \
                        and qreq.dispatch_twin(crit.push, now):
                    self.hedge.record_hedge()
                    self.stats.n_hedges += 1

    # -- drain --------------------------------------------------------------
    def _kv_free_slots(self) -> Optional[int]:
        """Claimable KV slots (None when no pool is attached). Accepts a
        ``KVCachePool`` or a bare ``SlotAllocator``."""
        if self.kv_pool is None:
            return None
        alloc = getattr(self.kv_pool, "alloc", self.kv_pool)
        return len(alloc.free)

    def drain(self, max_batches: Optional[int] = None,
              flush: Optional[bool] = None) -> List[Response]:
        """Form micro-batches and feed them through the
        :class:`~repro.scheduling.executor.DrainExecutor` until the
        queues are empty (or ``max_batches`` is reached, or the head is
        a decode request with no claimable KV slot — which stays
        queued). Batches are dispatched with full padded arrays +
        ``n_valid`` so shapes stay static across drains and device ops
        reuse cached executables instead of recompiling per fill level.

        ``flush`` controls what happens to the executor's in-flight
        window on return. Default (``None``): flush — every response
        for the batches formed here is returned, the pre-executor
        contract. ``flush=False`` (honored only at ``pipeline_depth >=
        2``; depth 1 keeps the historical sync-on-return behaviour
        bit-for-bit) leaves up to depth batches in flight so a serving
        loop draining one batch per iteration overlaps device compute
        with the next iteration's admission and batch formation —
        their responses surface from a later ``drain``/``poll``/
        ``flush`` call."""
        out: List[Response] = []
        n_done = 0
        if self.depth_controller is not None:
            # One control tick per drain call: backlog in formable
            # batches vs the freshest queue-delay signal (local EWMA,
            # or the attached ServiceTimeModel's queue-stage fit when
            # no response has landed here yet).
            self.executor.set_depth(self.depth_controller.tick(
                backlog_batches=self.queued_items
                / max(self.max_batch_items, 1),
                queue_delay_s=self._queue_delay_ewma))
        # KV budget threads across the whole drain: slots are claimed by
        # the decode executor after responses land, so batches formed in
        # one drain must share the snapshot taken here.
        kv_budget = self._kv_free_slots()
        while max_batches is None or n_done < max_batches:
            if self.hedge is not None:
                self._hedge_scan()
            batch = self.batcher.form(self.bank, kv_free=kv_budget)
            if batch is None:
                break
            if kv_budget is not None:
                kv_budget -= sum(
                    1 for q, _, _ in batch.slices
                    if MicroBatcher._needs_kv_slot(q))
            out.extend(self.executor.submit(batch))
            n_done += 1
        if flush is None or flush or self.executor.depth <= 1:
            out.extend(self.executor.flush())
        return out

    def poll(self) -> List[Response]:
        """Finalize already-completed in-flight batches without
        blocking (fresh stats for steal/hedge/autoscale scans)."""
        return self.executor.poll()

    def flush(self) -> List[Response]:
        """Block until every in-flight batch has landed."""
        return self.executor.flush()

    def _note_executor_error(self, batch: MicroBatch,
                             exc: Exception) -> None:
        """Executor ``on_error`` observer: strike every distinct work
        signature in the failed batch. Innocent requests co-batched
        with a poison pill collect strikes too, but their signatures
        decay back to zero the next time they complete cleanly
        (``record_success``) — only work that fails persistently
        crosses the k-strike threshold."""
        sigs = {work_signature(qreq.request.item_keys)
                for qreq, _, _ in batch.slices}
        for sig in sorted(sigs):
            self.quarantine.record_failure(sig)

    def _rescue_responses(self, batch: MicroBatch,
                          exc: Exception) -> List[Response]:
        """Exception-mid-window recovery: a batch whose dispatch or
        finalize raised is answered from the average-trust prior —
        degraded service, never a dropped request (and never a torn
        window: the executor still finalizes every other in-flight
        batch). The error is counted, not re-raised: overload systems
        shed work, they don't shed the rest of the window."""
        self.stats.n_executor_errors += 1
        end = self._now()
        regime = self.offered_regime()
        responses: List[Response] = []
        for qreq, s, ln in batch.slices:
            rid = qreq.request.request_id
            if rid in self._answered:       # hedged twin already served
                self._answered.discard(rid)
                continue
            trust, tier, shed, latency, met = self._prior_answer(
                qreq.request, regime)
            responses.append(Response(
                request_id=rid, trust=trust, tier=tier,
                latency_s=latency, met_slo=met,
                shed=shed, priority=qreq.priority,
                reason=f"executor_error:{type(exc).__name__}",
                queue_delay_s=max(end - qreq.enqueue_t, 0.0),
                hedged=qreq.hedged))
            if qreq.hedged and self.hedge is not None:
                self._answered.add(rid)
        return responses

    def _split_responses(self, batch: MicroBatch,
                         shed: ShedResult) -> List[Response]:
        nv = batch.n_valid
        end = self._now()
        batch_start = end - shed.response_time_s
        self.stats.n_batches += 1
        self.stats.n_batched_items += nv
        if self.quarantine is not None and self.quarantine.any_tracked:
            # Clean completion: decay strikes / close half-open probes
            # for every signature this batch carried.
            for sig in sorted({work_signature(qreq.request.item_keys)
                               for qreq, _, _ in batch.slices}):
                self.quarantine.record_success(sig)
        if self.depth_controller is not None and batch.slices:
            # Latency signal for the adaptive-depth controller: EWMA of
            # per-batch queue delay (batch start - earliest enqueue).
            delay = max(batch_start
                        - min(q.enqueue_t for q, _, _ in batch.slices),
                        0.0)
            self._queue_delay_ewma = (
                delay if self._queue_delay_ewma is None
                else 0.7 * self._queue_delay_ewma + 0.3 * delay)
        responses: List[Response] = []
        for qreq, s, ln in batch.slices:
            rid = qreq.request.request_id
            if rid in self._answered:       # hedged twin already served
                self._answered.discard(rid)
                continue
            tier = shed.tier[s:s + ln]
            sub = ShedResult(
                trust=shed.trust[s:s + ln], tier=tier,
                regime=shed.regime,
                response_time_s=shed.response_time_s,
                deadline_eff_s=shed.deadline_eff_s,
                n_evaluated=int((tier == TIER_EVAL).sum()),
                n_cached=int((tier == TIER_CACHED).sum()),
                n_prior=int((tier == TIER_PRIOR).sum()),
                uload=shed.uload)
            latency = end - qreq.request.arrival_s
            responses.append(Response(
                request_id=rid, trust=sub.trust, tier=tier,
                latency_s=latency,
                met_slo=latency <= qreq.request.slo_s + 1e-9,
                shed=sub, priority=qreq.priority,
                queue_delay_s=max(batch_start - qreq.enqueue_t, 0.0),
                hedged=qreq.hedged))
            if qreq.hedged and self.hedge is not None:
                # Skip the twin queued in THIS scheduler later. When the
                # twin lives on another replica (cluster hedging, where
                # self.hedge is None), the ClusterCoordinator owns the
                # fleet-wide dedup instead.
                self._answered.add(rid)
        return responses
