"""Per-tenant token-bucket rate limiting (admission guard).

Buckets are denominated in *candidate items*, not requests: a "book"
flood of 276k result URLs from one tenant costs 276k tokens, so a
single tenant cannot monopolize evaluation capacity with a few huge
requests while staying under a request-count cap.

The clock is injected (``now``) so the limiter runs under the
simulator's deterministic ``SimClock`` as well as ``time.monotonic``.
``CRITICAL`` traffic bypasses the limiter entirely (see
``priorities.AdmissionPolicy``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass
class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s, capacity ``burst``."""
    rate: float                    # tokens (items) per second
    burst: float                   # bucket capacity
    tokens: float = field(default=math.nan)   # nan -> start full
    last_t: float = field(default=math.nan)

    def _refill(self, now: float) -> None:
        if math.isnan(self.tokens):
            self.tokens = self.burst
            self.last_t = now
            return
        dt = max(now - self.last_t, 0.0)
        self.tokens = min(self.burst, self.tokens + dt * self.rate)
        self.last_t = now

    def try_acquire(self, n: float, now: float) -> bool:
        """Take ``n`` tokens if available; never goes negative."""
        self._refill(now)
        if n <= self.tokens + 1e-9:
            self.tokens -= n
            return True
        return False

    def available(self, now: float) -> float:
        self._refill(now)
        return self.tokens


class TenantRateLimiter:
    """One bucket per tenant, lazily created from default parameters.

    ``math.inf`` defaults disable limiting (every acquire succeeds)
    so the scheduler works out of the box; per-tenant quotas are
    installed with :meth:`configure`.
    """

    def __init__(self, default_rate: float = math.inf,
                 default_burst: float = math.inf):
        self.default_rate = default_rate
        self.default_burst = default_burst
        self._buckets: Dict[str, TokenBucket] = {}

    def configure(self, tenant: str, rate: float, burst: float) -> None:
        """Install or retune a tenant's quota.

        Retuning adjusts the EXISTING bucket in place (tokens clamped
        to the new burst) — replacing it would refill to a full burst
        and forgive everything the tenant already consumed, letting a
        periodically-reconfigured quota (the cluster autoscaler) never
        actually bind.
        """
        b = self._buckets.get(tenant)
        if b is None:
            self._buckets[tenant] = TokenBucket(rate=rate, burst=burst)
            return
        b.rate = rate
        if not math.isnan(b.tokens):
            b.tokens = min(b.tokens, burst)
        b.burst = burst

    def _bucket(self, tenant: str) -> TokenBucket:
        b = self._buckets.get(tenant)
        if b is None:
            b = TokenBucket(rate=self.default_rate,
                            burst=self.default_burst)
            self._buckets[tenant] = b
        return b

    def allow(self, tenant: str, n_items: int, now: float) -> bool:
        b = self._bucket(tenant)
        if math.isinf(b.burst):
            return True
        return b.try_acquire(float(n_items), now)

    def snapshot(self, now: float) -> Dict[str, Tuple[float, float]]:
        """tenant -> (available tokens, burst) for observability."""
        return {t: (b.available(now), b.burst)
                for t, b in self._buckets.items()}
