"""One serving replica: an independent engine the coordinator can hold.

``ReplicaHandle`` wraps a full single-host serving stack — its own
``ServingEngine`` and therefore its own ``Scheduler`` /
``PriorityQueueBank`` / ``LoadShedder`` / ``LoadMonitor`` / Trust-DB
cache / average-trust prior, plus an optional ``KVCachePool`` for LM
decode — so replicas shed, cache, and calibrate *independently* (one
hot replica extending its deadline never slows a cold sibling, and a
cache poisoned on one host stays on that host).

Simulated fleets give every replica its **own** ``SimClock``
(independent hardware runs in parallel; a shared clock would serialize
the fleet). The coordinator keeps the timelines coherent by
fast-forwarding a replica's clock to each event's global timestamp
(``advance_to``) — an idle replica's clock only lags because nothing
has happened on it.

Elastic membership needs two more facilities per replica:

* **queue snapshot export** (``export_queue``) — pops every queued
  request in drain order (strict priority, EDF within class) so a
  leaving replica's backlog hands off to the ring's new owners without
  reordering any EDF head; ``import_queued`` is the receiving side.
* **cache delta tap** — the shedder's ``on_shed`` hook records the
  ``(url_key, trust)`` pairs of every FRESH evaluation (a Trust-DB
  cache fill); ``take_cache_deltas`` drains them for the coordinator's
  gossip bus and ``apply_trust_deltas`` folds a sibling's broadcast
  into this replica's Trust-DB (cache-only — the prior stays local, so
  a poisoned sibling can at worst pre-warm cache entries that evict).

With a retrieval front end attached (``repro.retrieval``), a replica
additionally OWNS an inverted-index ``shard`` — the merge of the
doc-partition stripes the ring assigns it under ``"docpart:p"`` keys.
Shards load on join, hand their postings off on graceful leave (next
to the warm Trust-DB handoff), and rebuild from the corpus after a
crash; the coordinator keeps the fleet-wide searcher pointed at the
live set.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrustIRConfig
from repro.core import trust_cache as TC
from repro.core.load_monitor import LoadMonitor
from repro.core.shedder import ShedResult, SimClock, TIER_EVAL
from repro.scheduling import (PriorityQueueBank, QueuedRequest, Scheduler,
                              SchedulerConfig)
from repro.serving.engine import ServingEngine


def _pow2_pad(a: np.ndarray) -> np.ndarray:
    """Zero-pad a 1-D array to the next power-of-two length (>= 1), so
    shape-specialized jit caches see O(log max_len) distinct shapes
    instead of one per observed length."""
    n = max(int(len(a)), 1)
    target = 1 << (n - 1).bit_length()
    if target == len(a):
        return a
    out = np.zeros(target, a.dtype)
    out[:len(a)] = a
    return out


class ReplicaHandle:
    def __init__(self, replica_id: str, cfg: TrustIRConfig,
                 evaluate_chunk: Callable, weight: float = 1.0,
                 sched_cfg: Optional[SchedulerConfig] = None,
                 sim_rate_items_per_s: Optional[float] = None,
                 kv_pool=None, request_ids=None,
                 drain_mode: Optional[str] = None,
                 evaluate_batch: Optional[Callable] = None,
                 retriever=None, feature_sharding=None):
        self.replica_id = replica_id
        self.weight = float(weight)
        # Doc-partitioned index shard this replica OWNS (the merge of
        # its ring stripes); None until the coordinator attaches one.
        # Ownership is about residency + handoff accounting — queries
        # scatter-gather across every live shard via the fleet searcher.
        self.shard = None
        # Mirror stripes this replica HOSTS for persistently slow
        # siblings (repro.fanout selective replication): slow replica
        # id -> its mirrored IndexShard. Hedged shard probes land
        # here; regular fan-out never queries a mirror (the primary
        # already answers for those docs — exactly one answer per
        # shard enters the merge).
        self.mirrors: Dict[str, object] = {}
        self.clock = (SimClock(sim_rate_items_per_s)
                      if sim_rate_items_per_s is not None else None)
        # Construction state kept for in-place restarts (rolling
        # restarts rebuild the engine under the same id/weight/shard).
        self._ctor = dict(cfg=cfg, evaluate_chunk=evaluate_chunk,
                          sched_cfg=sched_cfg,
                          sim_rate_items_per_s=sim_rate_items_per_s,
                          kv_pool=kv_pool, request_ids=request_ids,
                          drain_mode=drain_mode,
                          evaluate_batch=evaluate_batch,
                          feature_sharding=feature_sharding)
        # drain_mode/evaluate_batch pass straight through: a fused
        # replica runs ONE jitted device step per micro-batch
        # (``core.fused_shedder``) instead of the host chunk loop.
        self.engine = ServingEngine(cfg, evaluate_chunk,
                                    sim_clock=self.clock,
                                    sched_cfg=sched_cfg,
                                    kv_pool=kv_pool,
                                    request_ids=request_ids,
                                    drain_mode=drain_mode,
                                    evaluate_batch=evaluate_batch,
                                    retriever=retriever,
                                    feature_sharding=feature_sharding)
        # Responses the coordinator has already collected from
        # ``engine.completed`` (consumption cursor).
        self.n_collected = 0
        # Fresh-evaluation (key, trust) batches awaiting gossip pickup.
        self._cache_deltas: List[Tuple[np.ndarray, np.ndarray]] = []
        # Optional per-batch measurement tap (the coordinator's
        # ServiceTimeModel): called with (shed_result, warm) where warm
        # is False when the batch tripped a fresh jit compile — the
        # same exclusion rule the LoadMonitor applies.
        self.stats_tap: Optional[Callable[[ShedResult, bool], None]] = None
        self._excl_seen = self.warmup_exclusions()
        self.engine.shedder.on_shed = self._tap_shed

    # -- forwarding accessors ------------------------------------------------
    @property
    def scheduler(self) -> Scheduler:
        return self.engine.scheduler

    @property
    def bank(self) -> PriorityQueueBank:
        return self.scheduler.bank

    @property
    def monitor(self) -> LoadMonitor:
        return self.engine.monitor

    @property
    def queued_requests(self) -> int:
        return len(self.bank)

    @property
    def queued_items(self) -> int:
        return self.bank.n_items

    # -- queue snapshot (drain-and-handoff) ----------------------------------
    def export_queue(self) -> List[QueuedRequest]:
        """Pop EVERY queued request in drain order (strict priority,
        EDF within each class) — the leaving replica's backlog snapshot.
        The bank is empty afterwards."""
        out: List[QueuedRequest] = []
        while True:
            qreq = self.bank.pop_next()
            if qreq is None:
                return out
            out.append(qreq)

    def import_queued(self, qreq: QueuedRequest) -> bool:
        """Receive a handed-off request into this replica's bank (same
        priority class, original deadline — the EDF key travels with
        the request)."""
        return self.bank.push(qreq)

    # -- Trust-DB gossip taps ------------------------------------------------
    def _tap_shed(self, item_keys: np.ndarray, result: ShedResult
                  ) -> None:
        """``on_shed`` hook: record the cache fills (freshly EVALuated
        keys and their trust) this shed produced, and feed the batch's
        service measurement to the capacity tap (warmup-flagged by
        whether the WarmupGate excluded a fresh signature during it)."""
        evald = result.tier == TIER_EVAL
        if evald.any():
            self._cache_deltas.append(
                (np.asarray(item_keys)[evald].astype(np.uint32),
                 result.trust[evald].astype(np.float32)))
        if self.stats_tap is not None:
            excl = self.warmup_exclusions()
            warm = excl == self._excl_seen
            self._excl_seen = excl
            self.stats_tap(result, warm)

    def take_cache_deltas(self) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Drain the pending cache-fill deltas (coordinator-side gossip
        harvest; also resets the tap buffer)."""
        out, self._cache_deltas = self._cache_deltas, []
        return out

    def kv_free_slots(self) -> Optional[int]:
        """Claimable decode KV slots on this replica (None when no
        ``KVCachePool`` is attached — non-decode serving)."""
        return self.scheduler._kv_free_slots()

    def steal_cost(self, qreq: QueuedRequest,
                   thief: Optional["ReplicaHandle"] = None) -> float:
        """Estimated evaluation cost of serving ``qreq`` HERE: items
        that would miss this replica's Trust-DB (a hit costs a probe, a
        miss costs a full evaluator forward). Cost-aware stealing ranks
        steal candidates by this, so a chunk of cache-hot requests is
        not shipped to a sibling whose cold cache would re-evaluate it
        while cache-cold work stays behind.

        With a ``thief`` named, decode KV-slot pressure folds in: a
        decode request (``needs_kv_slot``) scored against a thief with
        zero claimable ``KVCachePool`` slots costs ``-inf`` — it can
        make no progress there (the thief's batcher would just re-queue
        it), so the steal picker always prefers any other candidate,
        and the coordinator vetoes the migration outright if the picker
        had nothing else to offer."""
        if thief is not None \
                and getattr(qreq.request, "needs_kv_slot", False):
            free = thief.kv_free_slots()
            if free is not None and free <= 0:
                return float("-inf")
        keys = np.asarray(qreq.request.item_keys)
        if len(keys) == 0:
            return 0.0
        # Pad to the next power of two: steal scans probe with every
        # request's (Zipf-distributed) candidate count, and each fresh
        # length would otherwise trace+compile a new lookup — O(log)
        # distinct shapes keeps the jit cache warm. Key 0 is the cache
        # sentinel, so padding can never hit.
        padded = _pow2_pad(keys.astype(np.uint32))
        _, hit = TC.lookup(self.engine.shedder.cache,
                           jnp.asarray(padded, jnp.uint32))
        return float(len(keys) - int(np.asarray(hit).sum()))

    # -- warm-state handoff (graceful leave) ---------------------------------
    def export_cache(self, top_k: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """The ``top_k`` FRESHEST ``(url_key, trust)`` Trust-DB entries
        (by insertion age) — the warm-state complement of
        ``export_queue``. A gracefully leaving replica ships these to
        the ring's new owners through the same ``apply_trust_deltas``
        path gossip uses, so its tenants' hot URLs stay answered from
        cache instead of re-warming one duplicate evaluation at a
        time."""
        cache = self.engine.shedder.cache
        keys = np.asarray(cache["keys"]).reshape(-1)
        vals = np.asarray(cache["values"]).reshape(-1)
        age = np.asarray(cache["age"]).reshape(-1)
        live = keys != 0
        keys, vals, age = keys[live], vals[live], age[live]
        if len(keys) > top_k:
            sel = np.argpartition(-age, top_k - 1)[:top_k]
            keys, vals = keys[sel], vals[sel]
        return keys.astype(np.uint32), vals.astype(np.float32)

    def apply_trust_deltas(self, keys: np.ndarray,
                           values: np.ndarray) -> None:
        """Fold a sibling's gossiped (key, trust) pairs into this
        replica's Trust-DB cache. Inserts only — the average-trust
        prior stays strictly local.

        Padded to the next power of two before the device insert:
        gossip deltas arrive in arbitrary lengths, and at fleet scale
        (48+ replicas x one apply per sibling per round) compiling a
        fresh insert per length dominated the drain loop. ``insert``
        masks key 0 itself, so zero padding is dropped in-kernel."""
        if len(keys) == 0:
            return
        pk = _pow2_pad(np.asarray(keys, np.uint32))
        pv = _pow2_pad(np.asarray(values, np.float32))
        sh = self.engine.shedder
        sh.cache = TC.insert(sh.cache,
                             jnp.asarray(pk, jnp.uint32),
                             jnp.asarray(pv, jnp.float32),
                             jnp.ones((len(pk),), bool))

    # -- rolling restart -----------------------------------------------------
    def restart(self, *, now_t: float, downtime_s: float = 0.0) -> None:
        """Rebuild the serving stack in place (coordinated rolling
        restart). The handle keeps its identity — ``replica_id``,
        ``weight``, its ring-owned ``shard`` and hosted ``mirrors`` —
        but the engine comes back cold: fresh scheduler/bank/shedder/
        monitor state, empty Trust-DB cache, reset local prior, and a
        clean completed-responses log (the coordinator banks the old
        scheduler counters BEFORE calling this). The fresh simulated
        clock lands at ``now_t + downtime_s`` so post-restart work is
        stamped after the outage window, never before it.

        One thing deliberately SURVIVES the rebuild: the poison
        quarantine's breaker state. Forgetting it would make every
        rolling-restart wave re-eat ``k`` poison strikes per known-bad
        signature, so the old breakers are banked across the rebuild
        (next to the coordinator's scheduler-counter banking) and
        adopted by the fresh quarantine."""
        c = self._ctor
        rate = c["sim_rate_items_per_s"]
        old_quarantine = self.engine.scheduler.quarantine
        self.clock = SimClock(rate) if rate is not None else None
        retriever = getattr(self.engine, "retriever", None)
        self.engine = ServingEngine(c["cfg"], c["evaluate_chunk"],
                                    sim_clock=self.clock,
                                    sched_cfg=c["sched_cfg"],
                                    kv_pool=c["kv_pool"],
                                    request_ids=c["request_ids"],
                                    drain_mode=c["drain_mode"],
                                    evaluate_batch=c["evaluate_batch"],
                                    retriever=retriever,
                                    feature_sharding=c[
                                        "feature_sharding"])
        new_quarantine = self.engine.scheduler.quarantine
        if old_quarantine is not None and new_quarantine is not None:
            new_quarantine.adopt(old_quarantine)
        self.n_collected = 0
        self._cache_deltas = []
        self._excl_seen = self.warmup_exclusions()
        self.engine.shedder.on_shed = self._tap_shed
        self.advance_to(float(now_t) + float(downtime_s))

    # -- jit prewarm (feedforward joins) --------------------------------------
    def warmup_exclusions(self) -> int:
        """Lifetime count of WarmupGate first-sight exclusions on this
        replica's shedder — zero NEW exclusions across a batch means the
        batch ran entirely jit-warm."""
        gate = getattr(self.engine.shedder, "_warmup", None)
        return int(gate.n_excluded) if gate is not None else 0

    def prewarm(self, feature_schema: Dict[str, Tuple[Tuple[int, ...], str]],
                n_items: int) -> None:
        """Prime the evaluator at production shapes BEFORE the ring
        routes real traffic here, so a feedforward join never lands
        jit-cold mid-wave.

        Runs one synthetic full batch (``n_items`` at the live fleet's
        feature schema) straight through the shedder — deliberately NOT
        via the scheduler, so submit/enqueue counters and the no-drop
        accounting never see it. Serving state the synthetic batch
        would dirty is snapshotted and restored: Trust-DB cache, local
        prior, gossip delta tap, and the simulated clock (prewarm work
        is not real work). What survives is exactly the point — the jit
        caches and the WarmupGate's seen-signature set."""
        sh = self.engine.shedder
        n = max(int(n_items), 1)
        # Key range far above organic url_ids, so the synthetic lookup/
        # insert can never alias a real entry mid-call (the cache
        # snapshot is restored afterwards regardless).
        keys = (np.arange(n, dtype=np.int64) % 0x0FFFFFFF
                + 0xF0000000).astype(np.uint32)
        buckets = np.zeros(n, np.int32)
        feats = {k: np.zeros((n,) + tuple(shape), dtype=dtype)
                 for k, (shape, dtype) in feature_schema.items()}
        cache_snap, prior_snap = sh.cache, sh.prior
        deltas_snap, self._cache_deltas = self._cache_deltas, []
        t_snap = self.clock.t if self.clock is not None else None
        try:
            sh.process(keys, buckets, feats)
        finally:
            sh.cache, sh.prior = cache_snap, prior_snap
            self._cache_deltas = deltas_snap
            if self.clock is not None and t_snap is not None:
                self.clock.t = t_snap
            self._excl_seen = self.warmup_exclusions()

    # -- time -----------------------------------------------------------------
    def now(self) -> float:
        return self.engine._now()

    def advance_to(self, t: float) -> None:
        """Fast-forward a simulated clock to global time ``t`` (no-op on
        wall clocks, and never rewinds)."""
        if self.clock is not None:
            self.clock.t = max(self.clock.t, t)

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        return (f"ReplicaHandle({self.replica_id!r}, w={self.weight}, "
                f"queued={self.queued_requests})")
