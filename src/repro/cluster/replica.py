"""One serving replica: an independent engine the coordinator can hold.

``ReplicaHandle`` wraps a full single-host serving stack — its own
``ServingEngine`` and therefore its own ``Scheduler`` /
``PriorityQueueBank`` / ``LoadShedder`` / ``LoadMonitor`` / Trust-DB
cache / average-trust prior, plus an optional ``KVCachePool`` for LM
decode — so replicas shed, cache, and calibrate *independently* (one
hot replica extending its deadline never slows a cold sibling, and a
cache poisoned on one host stays on that host).

Simulated fleets give every replica its **own** ``SimClock``
(independent hardware runs in parallel; a shared clock would serialize
the fleet). The coordinator keeps the timelines coherent by
fast-forwarding a replica's clock to each event's global timestamp
(``advance_to``) — an idle replica's clock only lags because nothing
has happened on it.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.configs.base import TrustIRConfig
from repro.core.load_monitor import LoadMonitor
from repro.core.shedder import SimClock
from repro.scheduling import (PriorityQueueBank, Scheduler,
                              SchedulerConfig)
from repro.serving.engine import ServingEngine


class ReplicaHandle:
    def __init__(self, replica_id: str, cfg: TrustIRConfig,
                 evaluate_chunk: Callable, weight: float = 1.0,
                 sched_cfg: Optional[SchedulerConfig] = None,
                 sim_rate_items_per_s: Optional[float] = None,
                 kv_pool=None, request_ids=None,
                 drain_mode: Optional[str] = None,
                 evaluate_batch: Optional[Callable] = None):
        self.replica_id = replica_id
        self.weight = float(weight)
        self.clock = (SimClock(sim_rate_items_per_s)
                      if sim_rate_items_per_s is not None else None)
        # drain_mode/evaluate_batch pass straight through: a fused
        # replica runs ONE jitted device step per micro-batch
        # (``core.fused_shedder``) instead of the host chunk loop.
        self.engine = ServingEngine(cfg, evaluate_chunk,
                                    sim_clock=self.clock,
                                    sched_cfg=sched_cfg,
                                    kv_pool=kv_pool,
                                    request_ids=request_ids,
                                    drain_mode=drain_mode,
                                    evaluate_batch=evaluate_batch)
        # Responses the coordinator has already collected from
        # ``engine.completed`` (consumption cursor).
        self.n_collected = 0

    # -- forwarding accessors ------------------------------------------------
    @property
    def scheduler(self) -> Scheduler:
        return self.engine.scheduler

    @property
    def bank(self) -> PriorityQueueBank:
        return self.scheduler.bank

    @property
    def monitor(self) -> LoadMonitor:
        return self.engine.monitor

    @property
    def queued_requests(self) -> int:
        return len(self.bank)

    @property
    def queued_items(self) -> int:
        return self.bank.n_items

    # -- time -----------------------------------------------------------------
    def now(self) -> float:
        return self.engine._now()

    def advance_to(self, t: float) -> None:
        """Fast-forward a simulated clock to global time ``t`` (no-op on
        wall clocks, and never rewinds)."""
        if self.clock is not None:
            self.clock.t = max(self.clock.t, t)

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        return (f"ReplicaHandle({self.replica_id!r}, w={self.weight}, "
                f"queued={self.queued_requests})")
