"""Multi-replica serving fleet on top of ``repro.scheduling``.

Fleet request lifecycle (who owns each hop):

    route    cluster.routing                consistent-hash ring maps
       |                                    tenant -> replica shard
       |                                    (weighted vnodes, minimal
       |                                    remap on join/leave)
    admit    replica's own Scheduler        PR-1 ladder vs THAT
       |                                    replica's regime; explicit
       |                                    prior-answered rejections
    steal    cluster.coordinator            hot bank -> idle sibling,
       |                                    from the BACK of the lowest
       |                                    non-empty class (EDF heads
       |                                    never reorder)
    drain    cluster.coordinator            one micro-batch per replica
       |                                    per round (round-robin)
    hedge    distribution.fault_tolerance   stuck requests race a twin
       |                                    on a REAL backup replica;
       |                                    first completion wins,
       |                                    loser deduplicated
    adapt    cluster.autoscale_watermarks   fleet LoadMonitor EWMA ->
                                            adaptive AdmissionPolicy
                                            watermarks + tenant quotas

Every replica is a full independent serving stack (own shedder, cache,
prior, monitor — ``cluster.replica``); ``n_replicas=1`` degenerates to
the single-host PR-1 behaviour exactly.
"""
from repro.cluster.autoscale_watermarks import (ClusterLoadSnapshot,
                                                WatermarkAutoscaler)
from repro.cluster.coordinator import (ClusterConfig, ClusterCoordinator,
                                       ClusterStats)
from repro.cluster.replica import ReplicaHandle
from repro.cluster.routing import ConsistentHashRing, stable_hash

__all__ = [
    "ConsistentHashRing", "stable_hash",
    "ReplicaHandle",
    "ClusterConfig", "ClusterCoordinator", "ClusterStats",
    "WatermarkAutoscaler", "ClusterLoadSnapshot",
]
