"""Multi-replica serving fleet on top of ``repro.scheduling``.

Fleet request lifecycle (who owns each hop):

    route      cluster.routing                consistent-hash ring maps
       |                                      tenant -> replica shard
       |                                      (weighted vnodes, minimal
       |                                      remap on join/leave,
       |                                      fencing for drains)
    admit      replica's own Scheduler        PR-1 ladder vs THAT
       |                                      replica's regime; explicit
       |                                      prior-answered rejections
    steal      cluster.coordinator            hot bank -> idle sibling,
       |                                      from the BACK of the lowest
       |                                      non-empty class (EDF heads
       |                                      never reorder)
    drain      cluster.coordinator            one micro-batch per replica
       |                                      per round (round-robin)
    hedge      distribution.fault_tolerance   stuck requests race a twin
       |                                      on a REAL backup replica;
       |                                      first completion wins,
       |                                      loser deduplicated
    gossip     cluster.gossip                 fresh Trust-DB cache fills
       |                                      reach siblings on a bounded
       |                                      per-round budget (hot URLs
       |                                      evaluated once fleet-wide):
       |                                      O(n^2) broadcast, or
       |                                      epidemic peer-sampling push
       |                                      (O(log n) fanout, relayed)
       |                                      + anti-entropy pull —
       |                                      O(n log n) per round
    forecast   cluster.capacity               feedforward planner: NHPP
       |                                      arrival-rate extrapolation
       |                                      warmup_lead_s ahead ->
       |                                      forecast pressure folded
       |                                      into the SAME autoscaler
       |                                      vote (shared cooldown);
       |                                      per-stage ServiceTimeModel
       |                                      fitted from live drain
       |                                      stats feeds what-if
       |                                      predict(n, depth, batch)
    prewarm    cluster.replica                planner-triggered joins
       |                                      jit-compile the batch
       |                                      shape on synthetic keys
       |                                      BEFORE the ring unfences
       |                                      them (cache/prior/clock
       |                                      snapshot-restored, so
       |                                      prewarm leaves no state)
    adapt      cluster.autoscale_watermarks   fleet LoadMonitor EWMA ->
       |                                      adaptive AdmissionPolicy
       |                                      watermarks + tenant quotas;
       |                                      steal/hedge/autoscale scans
       |                                      read hot/cold replicas from
       |                                      one per-round
       |                                      ``ReplicaLoadHeap``
       |                                      (O(log n) per steal, not a
       |                                      full re-sort)
    restart    cluster.coordinator            coordinated rolling
       |                                      restarts in ring-disjoint
       |                                      waves: fence + handoff,
       |                                      engine rebuilt in place,
       |                                      membership held steady
    join/leave cluster.coordinator            runtime membership: joins
                                              rebalance minimally; a
                                              leave fences + drains its
                                              backlog to the ring's new
                                              owners (EDF order, hedge
                                              twins deduped); a crash
                                              replays the admission
                                              journal; the autoscaler's
                                              membership vote drives
                                              both between min/max
                                              replica bounds

Every replica is a full independent serving stack (own shedder, cache,
prior, monitor — ``cluster.replica``); ``n_replicas=1`` degenerates to
the single-host PR-1 behaviour exactly.
"""
from repro.cluster.autoscale_watermarks import (ClusterLoadSnapshot,
                                                WatermarkAutoscaler)
from repro.cluster.capacity import (CapacityPrediction, ForecastPlanner,
                                    ForecastSnapshot, ServiceTimeModel,
                                    StageStats, predict)
from repro.cluster.coordinator import (ClusterConfig, ClusterCoordinator,
                                       ClusterStats)
from repro.cluster.gossip import (GOSSIP_MODES, GossipStats, TrustDelta,
                                  TrustGossipBus)
from repro.cluster.loadindex import ReplicaLoadHeap
from repro.cluster.replica import ReplicaHandle
from repro.cluster.routing import ConsistentHashRing, stable_hash

__all__ = [
    "ConsistentHashRing", "stable_hash",
    "ReplicaHandle", "ReplicaLoadHeap",
    "ClusterConfig", "ClusterCoordinator", "ClusterStats",
    "WatermarkAutoscaler", "ClusterLoadSnapshot",
    "ServiceTimeModel", "StageStats", "CapacityPrediction", "predict",
    "ForecastPlanner", "ForecastSnapshot",
    "TrustGossipBus", "TrustDelta", "GossipStats", "GOSSIP_MODES",
]
