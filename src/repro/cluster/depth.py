"""Adaptive pipeline depth: a bounded controller over the drain window.

``TrustIRConfig.pipeline_depth`` was a static choice: deep windows buy
throughput (batch N+2 stages while N computes) but charge every batch
the latency of the window ahead of it, so the right depth depends on
whether the replica is throughput-bound (backlog keeps the window full)
or latency-bound (queue delay eats the deadline). This module closes
that loop per replica:

``DepthController``
    one controller per ``Scheduler``/``DrainExecutor``. Each tick reads
    two signals — the replica's backlog in batches (throughput-bound
    when it could keep a deeper window full) and the measured queue
    delay against the deadline (latency-bound when waiting already
    burns the budget) — and votes deepen / shallow / hold. The queue
    delay falls back to the per-stage service-time fit
    (``cluster.capacity.ServiceTimeModel``, STAGE_QUEUE p99) when the
    caller has no fresher sample, so the controller is driven by the
    same fits the capacity planner maintains.

Flap control: a vote only applies after ``hysteresis`` CONSECUTIVE
same-direction votes, every applied change starts a ``cooldown_ticks``
hold (votes do not accumulate through it), and depth moves ONE step at
a time inside ``[min_depth, max_depth]`` — the static config remains as
the clamp (``max_depth = cfg.pipeline_depth``), so adaptive depth can
never exceed what the operator provisioned. Alternating pressure
therefore never changes depth (property-tested in
``tests/test_adaptive_depth.py``).

The coordinator wires the fleet's ``ServiceTimeModel`` into each
replica's controller when capacity planning is attached; each drain
round then re-ticks the controller and applies the decision through
``DrainExecutor.set_depth`` — per replica, every round, with fresh
stats (the scheduler does the same when it drains standalone).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.cluster.capacity import STAGE_QUEUE, ServiceTimeModel

VOTE_DEEPEN = 1
VOTE_HOLD = 0
VOTE_SHALLOW = -1


def controller_from_config(cfg) -> Optional["DepthController"]:
    """Build the configured controller (None when adaptive depth is
    off — the static-depth behaviour is then untouched)."""
    if not getattr(cfg, "adaptive_depth", False):
        return None
    return DepthController(
        min_depth=getattr(cfg, "adaptive_depth_min", 1),
        max_depth=max(int(getattr(cfg, "pipeline_depth", 1)), 1),
        deadline_s=cfg.deadline_s,
        deepen_backlog_batches=getattr(
            cfg, "adaptive_depth_backlog_batches", 2.0),
        latency_frac=getattr(cfg, "adaptive_depth_latency_frac", 0.5),
        hysteresis=getattr(cfg, "adaptive_depth_hysteresis", 2),
        cooldown_ticks=getattr(cfg, "adaptive_depth_cooldown_ticks", 2))


@dataclass
class DepthDecision:
    depth: int
    vote: int
    changed: bool
    backlog_batches: float
    queue_delay_s: Optional[float]


class DepthController:
    """Bounded hysteresis controller for the drain window depth.

    Starts at ``max_depth`` (the static config), so an idle or
    well-provisioned replica behaves exactly like the pre-adaptive
    system until a latency signal argues for shallowing.
    """

    def __init__(self, *, min_depth: int = 1, max_depth: int = 2,
                 deadline_s: float = 0.5,
                 deepen_backlog_batches: float = 2.0,
                 latency_frac: float = 0.5,
                 hysteresis: int = 2, cooldown_ticks: int = 2,
                 model: Optional[ServiceTimeModel] = None):
        if min_depth < 1:
            raise ValueError("min_depth must be >= 1")
        if max_depth < min_depth:
            raise ValueError("max_depth must be >= min_depth")
        self.min_depth = int(min_depth)
        self.max_depth = int(max_depth)
        self.deadline_s = float(deadline_s)
        self.deepen_backlog_batches = float(deepen_backlog_batches)
        self.latency_frac = float(latency_frac)
        self.hysteresis = max(int(hysteresis), 1)
        self.cooldown_ticks = max(int(cooldown_ticks), 0)
        self.model = model
        self.depth = self.max_depth
        self.n_ticks = 0
        self.n_changes = 0
        self._streak_vote = VOTE_HOLD
        self._streak = 0
        self._cooldown = 0
        self.last: Optional[DepthDecision] = None

    # -- signals ------------------------------------------------------------
    def _queue_delay(self, sample: Optional[float]) -> Optional[float]:
        if sample is not None:
            return float(sample)
        if self.model is not None:
            return self.model.stages[STAGE_QUEUE].percentile_s(99.0)
        return None

    def _vote(self, backlog_batches: float,
              queue_delay_s: Optional[float]) -> int:
        latency_bound = (queue_delay_s is not None
                         and queue_delay_s
                         > self.latency_frac * self.deadline_s)
        if latency_bound and self.depth > self.min_depth:
            return VOTE_SHALLOW
        if (not latency_bound
                and backlog_batches >= self.deepen_backlog_batches
                and self.depth < self.max_depth):
            return VOTE_DEEPEN
        return VOTE_HOLD

    # -- the tick -----------------------------------------------------------
    def tick(self, *, backlog_batches: float,
             queue_delay_s: Optional[float] = None) -> int:
        """One control step; returns the (possibly updated) depth."""
        self.n_ticks += 1
        changed = False
        qd = self._queue_delay(queue_delay_s)
        vote = self._vote(float(backlog_batches), qd)
        if self._cooldown > 0:
            # Votes do not accumulate through a cooldown: an applied
            # change must prove itself before the next one.
            self._cooldown -= 1
            self._streak = 0
            self._streak_vote = VOTE_HOLD
        elif vote == VOTE_HOLD:
            self._streak = 0
            self._streak_vote = VOTE_HOLD
        else:
            if vote == self._streak_vote:
                self._streak += 1
            else:
                self._streak_vote = vote
                self._streak = 1
            if self._streak >= self.hysteresis:
                new = min(max(self.depth + vote, self.min_depth),
                          self.max_depth)
                changed = new != self.depth
                if changed:
                    self.depth = new
                    self.n_changes += 1
                self._streak = 0
                self._streak_vote = VOTE_HOLD
                self._cooldown = self.cooldown_ticks
        self.last = DepthDecision(depth=self.depth, vote=vote,
                                  changed=changed,
                                  backlog_batches=float(backlog_batches),
                                  queue_delay_s=qd)
        return self.depth

    def stats(self) -> dict:
        last = self.last
        return {
            "depth": self.depth,
            "min_depth": self.min_depth,
            "max_depth": self.max_depth,
            "n_ticks": self.n_ticks,
            "n_changes": self.n_changes,
            "last_vote": last.vote if last else VOTE_HOLD,
            "last_backlog_batches":
                last.backlog_batches if last else 0.0,
            "last_queue_delay_s":
                (last.queue_delay_s if last and
                 last.queue_delay_s is not None else 0.0),
        }
