"""Cross-replica Trust-DB gossip: cache-fill deltas on a bounded budget.

Every replica evaluates, caches, and calibrates independently
(``cluster.replica``) — which means a correlated flood (the same hot
URLs arriving at many tenants at once) pays one full trust evaluation
PER replica for every hot URL. This module closes the ROADMAP open item
with the cheapest coordination that helps: when a replica's shedder
*freshly evaluates* a URL (a Trust-DB cache fill — the only moment new
information exists), it publishes the ``(url_key, trust)`` delta to a
coordinator-owned bus; once per drain round the bus broadcasts the
freshest deltas to every *other* replica's Trust-DB, so the next
replica to see that URL answers from cache (``TIER_CACHED``) instead of
re-evaluating.

Design constraints, in load-shedding spirit:

* **bounded budget** — at most ``budget_items_per_round`` ``(key,
  value)`` pairs are broadcast per drain round; overflow deltas are
  DROPPED (and counted), never queued unboundedly. Gossip is an
  optimization, not a correctness dependency: a dropped delta only
  costs a duplicate evaluation later.
* **generation-stamped** — each publish carries a monotonically
  increasing generation; a delta that is no longer the newest value for
  its key (a slower replica's stale re-evaluation, an out-of-order
  arrival) is dropped at broadcast time instead of overwriting fresher
  trust.
* **no echo** — deltas are never delivered back to their origin
  replica, and deliveries insert straight into sibling Trust-DB caches
  (``TC.insert``) without re-triggering the shed tap, so gossip cannot
  loop.

The bus is a coordinator-local object standing in for the lightweight
UDP/membership-protocol fanout a multi-host deployment would use; the
budget and staleness rules are the part that transfers.

Two delivery modes (``mode=``):

* ``"broadcast"`` (default, the original behaviour) — every kept delta
  reaches every non-origin replica in the same round. Exact and
  instant, but the per-round message count is ``deltas x (n-1)`` —
  an O(n^2) wall that caps fleet size (48 replicas = 47 messages per
  delta per round).
* ``"epidemic"`` — peer-sampled push + anti-entropy pull. Each kept
  delta is pushed to ``ceil(log2 n)`` deterministically sampled
  non-origin peers, and once per round every replica pulls from ONE
  sampled peer the recent deltas that peer holds and it lacks (the
  classic rumor-mongering + anti-entropy pair: push spreads a delta to
  most of the fleet in O(log n) rounds w.h.p., pull guarantees the
  stragglers converge). Messages per round are bounded by
  ``deltas x ceil(log2 n) + 2n`` = O(n log n) — measured in
  ``GossipStats.max_round_messages`` and asserted by
  ``benchmarks/bench_fleet.py`` at n=48. Sampling is seeded and keyed
  on (seed, generation) / (seed, round, replica), so a replayed trace
  gossips bit-identically.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class GossipStats:
    n_published: int = 0        # pairs offered by replicas (cache fills)
    n_broadcast: int = 0        # pairs actually broadcast (budget-bound)
    n_applied: int = 0          # pair-deliveries into sibling caches
    n_dropped_budget: int = 0   # overflow pairs shed by the round budget
    n_dropped_stale: int = 0    # superseded-generation pairs dropped
    # Message accounting (one "message" = one delta delivered to one
    # replica, or one anti-entropy pull exchange) — what a wire
    # protocol would actually send, and what the O(n log n) bench gate
    # measures.
    n_rounds: int = 0
    n_messages: int = 0
    n_push_messages: int = 0
    n_pull_messages: int = 0    # pull exchanges (request + any reply)
    n_pull_applied: int = 0     # pairs delivered via anti-entropy pull
    max_round_messages: int = 0
    # What broadcast WOULD have sent for the same kept deltas
    # (deltas x (n-1)) — the O(n^2) contrast the epidemic mode avoids.
    n_broadcast_equiv: int = 0

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclass
class TrustDelta:
    """One published cache-fill batch from ``origin``."""
    origin: str
    keys: np.ndarray            # (n,) uint32 url keys
    values: np.ndarray          # (n,) float32 trust
    gen: int                    # generation stamp (monotone per bus)


GOSSIP_MODES = ("broadcast", "epidemic")


@dataclass
class _RelayEntry:
    """A recently pushed delta still spreading through the fleet
    (epidemic mode): ``reached`` tracks which replicas hold it, so
    anti-entropy pulls only move what the target actually lacks."""
    gen: int
    keys: np.ndarray
    values: np.ndarray
    reached: set


class TrustGossipBus:
    """Coordinator-owned delta bus: publish on cache fill, deliver
    once per drain round under a bounded per-round budget (broadcast
    to all siblings, or epidemic peer-sampled push + anti-entropy
    pull — see the module docstring)."""

    def __init__(self, budget_items_per_round: int = 256,
                 mode: str = "broadcast", seed: int = 0,
                 relay_log: int = 256):
        if budget_items_per_round <= 0:
            raise ValueError("gossip budget must be positive")
        if mode not in GOSSIP_MODES:
            raise ValueError(f"unknown gossip mode {mode!r}")
        self.budget_items_per_round = int(budget_items_per_round)
        self.mode = mode
        self._seed = int(seed) & 0xFFFFFFFF
        self._relay_cap = int(relay_log)
        self._relay: List[_RelayEntry] = []
        self._pending: Deque[TrustDelta] = deque()
        self._gen = itertools.count(1)
        # key -> newest generation seen; older deltas for the key are
        # stale and must not overwrite fresher trust on delivery.
        self._latest_gen: Dict[int, int] = {}
        self.stats = GossipStats()

    @property
    def n_pending(self) -> int:
        return sum(len(d.keys) for d in self._pending)

    def publish(self, origin: str, keys: np.ndarray, values: np.ndarray,
                gen: Optional[int] = None) -> int:
        """Enqueue a cache-fill delta batch from ``origin``. ``gen``
        defaults to a fresh (newest) generation; an explicit lower
        generation models a delayed/out-of-order publish and will be
        dropped as stale at broadcast time."""
        keys = np.asarray(keys, np.uint32)
        values = np.asarray(values, np.float32)
        if len(keys) == 0:
            return 0
        if len(keys) != len(values):
            raise ValueError("keys/values length mismatch")
        g = next(self._gen) if gen is None else int(gen)
        for k in keys.tolist():
            if g >= self._latest_gen.get(int(k), -1):
                self._latest_gen[int(k)] = g
        self._pending.append(TrustDelta(origin, keys, values, g))
        self.stats.n_published += len(keys)
        return len(keys)

    def flush(self, replicas: Sequence) -> int:
        """Deliver up to ``budget_items_per_round`` of the freshest
        pending pairs (overflow pending pairs are dropped — bounded
        memory, bounded per-round work), then run the mode's delivery:
        broadcast to every non-origin replica, or epidemic push to
        ``ceil(log2 n)`` sampled peers plus one anti-entropy pull per
        replica. Returns the number of pairs that spent budget."""
        budget = self.budget_items_per_round
        n_broadcast = 0
        kept: List[TrustDelta] = []
        # Newest publishes spend the budget first: under a sustained
        # flood the keys most likely to recur next round are the ones
        # siblings must hear about; the oldest overflow is shed.
        while self._pending:
            delta = self._pending.pop()
            fresh = np.asarray(
                [self._latest_gen.get(int(k), -1) <= delta.gen
                 for k in delta.keys.tolist()])
            self.stats.n_dropped_stale += int((~fresh).sum())
            keys, vals = delta.keys[fresh], delta.values[fresh]
            if len(keys) == 0:
                continue
            if n_broadcast >= budget:
                self.stats.n_dropped_budget += len(keys)
                continue
            take = min(len(keys), budget - n_broadcast)
            self.stats.n_dropped_budget += len(keys) - take
            kept.append(TrustDelta(delta.origin, keys[:take],
                                   vals[:take], delta.gen))
            n_broadcast += take
        n_live = len(replicas)
        round_msgs = 0
        if n_live > 1:
            if self.mode == "broadcast":
                round_msgs += self._deliver_broadcast(kept, replicas)
            else:
                round_msgs += self._push_epidemic(kept, replicas)
                round_msgs += self._anti_entropy_pull(replicas)
                self._prune_relay(replicas)
        self.stats.n_broadcast += n_broadcast
        self.stats.n_broadcast_equiv += len(kept) * max(n_live - 1, 0)
        self.stats.n_rounds += 1
        self.stats.n_messages += round_msgs
        if round_msgs > self.stats.max_round_messages:
            self.stats.max_round_messages = round_msgs
        return n_broadcast

    # -- delivery modes ------------------------------------------------------

    def _deliver_broadcast(self, kept: List[TrustDelta],
                           replicas: Sequence) -> int:
        """Original O(n^2) wall: every kept delta to every non-origin
        replica, one apply per target per round."""
        per_target: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
        msgs = 0
        for delta in kept:
            for rep in replicas:
                if rep.replica_id != delta.origin:
                    per_target.setdefault(rep.replica_id, []).append(
                        (delta.keys, delta.values))
                    msgs += 1
        self._apply_grouped(per_target, replicas)
        self.stats.n_push_messages += msgs
        return msgs

    def _push_epidemic(self, kept: List[TrustDelta],
                       replicas: Sequence) -> int:
        """Rumor-mongering push: each kept delta to ``ceil(log2 n)``
        sampled non-origin peers, sampling keyed on (seed, gen) so a
        replayed trace pushes to the same peers."""
        rids = sorted(rep.replica_id for rep in replicas)
        fanout = max(1, math.ceil(math.log2(max(len(rids), 2))))
        per_target: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
        msgs = 0
        for delta in kept:
            peers = [r for r in rids if r != delta.origin]
            rng = np.random.default_rng(
                (self._seed, 0x505A11, delta.gen))
            idx = rng.choice(len(peers),
                             size=min(fanout, len(peers)),
                             replace=False)
            targets = [peers[i] for i in sorted(idx.tolist())]
            for t in targets:
                per_target.setdefault(t, []).append(
                    (delta.keys, delta.values))
            msgs += len(targets)
            self._relay.append(_RelayEntry(
                delta.gen, delta.keys, delta.values,
                {delta.origin, *targets}))
        self._apply_grouped(per_target, replicas)
        self.stats.n_push_messages += msgs
        return msgs

    def _anti_entropy_pull(self, replicas: Sequence) -> int:
        """Once per round each replica pulls from ONE sampled peer the
        relay-log deltas that peer holds and it lacks: the convergence
        guarantee behind the probabilistic push (a straggler the push
        sampling missed catches up in expected O(1) pulls once most of
        the fleet holds the delta)."""
        from repro.cluster.routing import stable_hash
        rids = sorted(rep.replica_id for rep in replicas)
        by_id = {rep.replica_id: rep for rep in replicas}
        rnd = self.stats.n_rounds
        msgs = 0
        for rid in rids:
            peers = [r for r in rids if r != rid]
            rng = np.random.default_rng(
                (self._seed, 0xA17E, rnd,
                 stable_hash(rid) & 0xFFFFFFFF))
            peer = peers[int(rng.integers(len(peers)))]
            msgs += 1               # the digest request
            keys_l: List[np.ndarray] = []
            vals_l: List[np.ndarray] = []
            for e in self._relay:
                if peer not in e.reached or rid in e.reached:
                    continue
                fresh = np.asarray(
                    [self._latest_gen.get(int(k), -1) <= e.gen
                     for k in e.keys.tolist()])
                self.stats.n_dropped_stale += int((~fresh).sum())
                if fresh.any():
                    keys_l.append(e.keys[fresh])
                    vals_l.append(e.values[fresh])
                e.reached.add(rid)
            if keys_l:
                keys = np.concatenate(keys_l)
                vals = np.concatenate(vals_l)
                by_id[rid].apply_trust_deltas(keys, vals)
                msgs += 1           # the reply payload
                self.stats.n_applied += len(keys)
                self.stats.n_pull_applied += len(keys)
        self.stats.n_pull_messages += msgs
        return msgs

    def _apply_grouped(self, per_target: Dict[str, List[Tuple]],
                       replicas: Sequence) -> None:
        if not per_target:
            return
        by_id = {rep.replica_id: rep for rep in replicas}
        for rid, batches in per_target.items():
            keys = np.concatenate([k for k, _ in batches])
            vals = np.concatenate([v for _, v in batches])
            by_id[rid].apply_trust_deltas(keys, vals)
            self.stats.n_applied += len(keys)

    def _prune_relay(self, replicas: Sequence) -> None:
        """Drop fully-spread deltas; cap the log (oldest evicted — a
        delta nobody pulled in ``relay_log`` rounds of churn only
        costs a duplicate evaluation later, the gossip contract)."""
        live = {rep.replica_id for rep in replicas}
        self._relay = [e for e in self._relay
                       if not live.issubset(e.reached)]
        if len(self._relay) > self._relay_cap:
            self._relay = self._relay[-self._relay_cap:]
