"""Cross-replica Trust-DB gossip: cache-fill deltas on a bounded budget.

Every replica evaluates, caches, and calibrates independently
(``cluster.replica``) — which means a correlated flood (the same hot
URLs arriving at many tenants at once) pays one full trust evaluation
PER replica for every hot URL. This module closes the ROADMAP open item
with the cheapest coordination that helps: when a replica's shedder
*freshly evaluates* a URL (a Trust-DB cache fill — the only moment new
information exists), it publishes the ``(url_key, trust)`` delta to a
coordinator-owned bus; once per drain round the bus broadcasts the
freshest deltas to every *other* replica's Trust-DB, so the next
replica to see that URL answers from cache (``TIER_CACHED``) instead of
re-evaluating.

Design constraints, in load-shedding spirit:

* **bounded budget** — at most ``budget_items_per_round`` ``(key,
  value)`` pairs are broadcast per drain round; overflow deltas are
  DROPPED (and counted), never queued unboundedly. Gossip is an
  optimization, not a correctness dependency: a dropped delta only
  costs a duplicate evaluation later.
* **generation-stamped** — each publish carries a monotonically
  increasing generation; a delta that is no longer the newest value for
  its key (a slower replica's stale re-evaluation, an out-of-order
  arrival) is dropped at broadcast time instead of overwriting fresher
  trust.
* **no echo** — deltas are never delivered back to their origin
  replica, and deliveries insert straight into sibling Trust-DB caches
  (``TC.insert``) without re-triggering the shed tap, so gossip cannot
  loop.

The bus is a coordinator-local object standing in for the lightweight
UDP/membership-protocol fanout a multi-host deployment would use; the
budget and staleness rules are the part that transfers.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class GossipStats:
    n_published: int = 0        # pairs offered by replicas (cache fills)
    n_broadcast: int = 0        # pairs actually broadcast (budget-bound)
    n_applied: int = 0          # pair-deliveries into sibling caches
    n_dropped_budget: int = 0   # overflow pairs shed by the round budget
    n_dropped_stale: int = 0    # superseded-generation pairs dropped

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclass
class TrustDelta:
    """One published cache-fill batch from ``origin``."""
    origin: str
    keys: np.ndarray            # (n,) uint32 url keys
    values: np.ndarray          # (n,) float32 trust
    gen: int                    # generation stamp (monotone per bus)


class TrustGossipBus:
    """Coordinator-owned delta bus: publish on cache fill, broadcast
    once per drain round under a bounded per-round budget."""

    def __init__(self, budget_items_per_round: int = 256):
        if budget_items_per_round <= 0:
            raise ValueError("gossip budget must be positive")
        self.budget_items_per_round = int(budget_items_per_round)
        self._pending: Deque[TrustDelta] = deque()
        self._gen = itertools.count(1)
        # key -> newest generation seen; older deltas for the key are
        # stale and must not overwrite fresher trust on delivery.
        self._latest_gen: Dict[int, int] = {}
        self.stats = GossipStats()

    @property
    def n_pending(self) -> int:
        return sum(len(d.keys) for d in self._pending)

    def publish(self, origin: str, keys: np.ndarray, values: np.ndarray,
                gen: Optional[int] = None) -> int:
        """Enqueue a cache-fill delta batch from ``origin``. ``gen``
        defaults to a fresh (newest) generation; an explicit lower
        generation models a delayed/out-of-order publish and will be
        dropped as stale at broadcast time."""
        keys = np.asarray(keys, np.uint32)
        values = np.asarray(values, np.float32)
        if len(keys) == 0:
            return 0
        if len(keys) != len(values):
            raise ValueError("keys/values length mismatch")
        g = next(self._gen) if gen is None else int(gen)
        for k in keys.tolist():
            if g >= self._latest_gen.get(int(k), -1):
                self._latest_gen[int(k)] = g
        self._pending.append(TrustDelta(origin, keys, values, g))
        self.stats.n_published += len(keys)
        return len(keys)

    def flush(self, replicas: Sequence) -> int:
        """Broadcast up to ``budget_items_per_round`` of the freshest
        pending pairs to every replica except each pair's origin;
        overflow pending pairs are dropped (bounded memory, bounded
        per-round work). Returns the number of pairs broadcast."""
        budget = self.budget_items_per_round
        n_broadcast = 0
        per_target: Dict[str, List[Tuple[np.ndarray, np.ndarray]]] = {}
        # Newest publishes spend the budget first: under a sustained
        # flood the keys most likely to recur next round are the ones
        # siblings must hear about; the oldest overflow is shed.
        while self._pending:
            delta = self._pending.pop()
            fresh = np.asarray(
                [self._latest_gen.get(int(k), -1) <= delta.gen
                 for k in delta.keys.tolist()])
            self.stats.n_dropped_stale += int((~fresh).sum())
            keys, vals = delta.keys[fresh], delta.values[fresh]
            if len(keys) == 0:
                continue
            if n_broadcast >= budget:
                self.stats.n_dropped_budget += len(keys)
                continue
            take = min(len(keys), budget - n_broadcast)
            self.stats.n_dropped_budget += len(keys) - take
            keys, vals = keys[:take], vals[:take]
            n_broadcast += take
            for rep in replicas:
                if rep.replica_id != delta.origin:
                    per_target.setdefault(rep.replica_id, []).append(
                        (keys, vals))
        if per_target:
            by_id = {rep.replica_id: rep for rep in replicas}
            for rid, batches in per_target.items():
                keys = np.concatenate([k for k, _ in batches])
                vals = np.concatenate([v for _, v in batches])
                by_id[rid].apply_trust_deltas(keys, vals)
                self.stats.n_applied += len(keys)
        self.stats.n_broadcast += n_broadcast
        return n_broadcast
