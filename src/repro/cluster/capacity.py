"""Feedforward capacity planning: measured service times -> what-if -> joins.

The watermark autoscaler (``autoscale_watermarks.py``) is reactive: it
votes on a pressure EWMA that only rises once queues have already
built, so a diurnal ramp is served one EWMA lag late and the join lands
jit-cold in the middle of the wave.  Following the capacity-planning
line of work for vertical search engines (arxiv 1006.5059 in
PAPERS.md), this module closes the loop *ahead* of the breach:

``ServiceTimeModel``
    fits per-stage service-time distributions (retrieve, queue, batch,
    device step, gather) from the same measurements the serving path
    already makes — ``LoadMonitor`` observations (which inherit the
    WarmupGate exclusion and the executor's marginal-window charging)
    and per-batch drain stats tapped off the shedder.  The model is
    keyed by the configuration it measured (``drain_mode``,
    ``pipeline_depth``, batch budget) so fits are never blended across
    regimes that execute differently.

``predict(...)``
    a closed queueing-network what-if: replays a workload's arrival
    curve through a deterministic mini-model of the fleet (consistent-
    ring routing, per-replica batch queues, the real effective-deadline
    eval budget, fitted service rates) and returns predicted
    ``(throughput, p99)`` for a hypothetical ``(n_replicas, depth,
    batch)`` without running the fleet.

``ForecastPlanner``
    estimates the arrival curve's NHPP rate over a sliding window,
    linearly extrapolates it ``warmup_lead_s`` ahead, and converts the
    predicted rate into a *forecast pressure* (predicted utilization of
    the fleet's measured service rate).  The coordinator feeds that
    into ``WatermarkAutoscaler.membership_decision`` so scale-up
    triggers before the watermark breach — and through the same
    cooldown bookkeeping as a reactive vote, so feedforward and
    reactive joins can never double-fire inside one cooldown window.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.deadline import effective_deadline
from repro.cluster.routing import ConsistentHashRing


# ---------------------------------------------------------------------------
# per-stage accumulator
# ---------------------------------------------------------------------------

class StageStats:
    """Bounded per-stage accumulator of ``(n_items, elapsed_s)`` samples."""

    __slots__ = ("n", "sum_items", "sum_s", "_elapsed", "max_samples")

    def __init__(self, max_samples: int = 4096):
        self.n = 0
        self.sum_items = 0.0
        self.sum_s = 0.0
        self._elapsed: Deque[float] = deque(maxlen=max_samples)
        self.max_samples = max_samples

    def observe(self, n_items: float, elapsed_s: float) -> None:
        if elapsed_s < 0.0:
            return
        self.n += 1
        self.sum_items += float(n_items)
        self.sum_s += float(elapsed_s)
        self._elapsed.append(float(elapsed_s))

    @property
    def rate_items_per_s(self) -> Optional[float]:
        """Aggregate items/s — the fit a queueing model wants, robust to
        per-sample jitter because it weights by window length."""
        if self.sum_s <= 0.0:
            return None
        return self.sum_items / self.sum_s

    def mean_s(self) -> Optional[float]:
        if self.n == 0:
            return None
        return self.sum_s / self.n

    def percentile_s(self, q: float) -> Optional[float]:
        if not self._elapsed:
            return None
        return float(np.percentile(np.asarray(self._elapsed), q))

    def summary(self) -> Dict[str, Optional[float]]:
        return {
            "n": self.n,
            "mean_s": self.mean_s(),
            "p50_s": self.percentile_s(50.0),
            "p99_s": self.percentile_s(99.0),
            "rate_items_per_s": self.rate_items_per_s,
        }


# ---------------------------------------------------------------------------
# service-time model
# ---------------------------------------------------------------------------

STAGE_RETRIEVE = "retrieve"
STAGE_QUEUE = "queue"
STAGE_BATCH = "batch"
STAGE_DEVICE = "device"
STAGE_GATHER = "gather"
STAGES = (STAGE_RETRIEVE, STAGE_QUEUE, STAGE_BATCH, STAGE_DEVICE,
          STAGE_GATHER)


class ServiceTimeModel:
    """Per-stage service-time fit for ONE ``(drain_mode, pipeline_depth,
    batch_items)`` serving configuration.

    Stage sources, and why each is honest:

    - ``device``: tapped off ``LoadMonitor.observe`` via ``on_observe``.
      The monitor only sees windows the WarmupGate admitted (jit compile
      excluded) and, at ``pipeline_depth > 1``, only the *marginal*
      window since the previous completion — so fitted device rates are
      invariant to depth instead of double-counting overlapped work.
    - ``batch``: whole-batch drain service tapped off the shedder
      (``uload``, ``n_evaluated``, ``response_time_s``).  Warmup batches
      — detected by the WarmupGate's exclusion counter moving — are
      dropped here too, with the drop counted in
      ``n_warmup_excluded``.  On simulated clocks this stage is exact
      (SimClock charges ``n_evaluated / rate``).
    - ``queue``: scheduler-measured ``Response.queue_delay_s``.
    - ``retrieve`` / ``gather``: front-end per-query times fed by the
      caller (fan-out searcher shard times / gather makespans).
    """

    def __init__(self, cfg, *, drain_mode: str, pipeline_depth: int,
                 batch_items: int):
        self.cfg = cfg
        self.drain_mode = str(drain_mode)
        self.pipeline_depth = int(pipeline_depth)
        self.batch_items = int(batch_items)
        self.stages: Dict[str, StageStats] = {s: StageStats() for s in STAGES}
        self.n_warmup_excluded = 0
        self._uload_total = 0.0
        self._evaluated_total = 0.0

    # -- taps ---------------------------------------------------------------

    def attach_monitor(self, monitor) -> None:
        """Subscribe to a ``LoadMonitor`` — device-step windows arrive
        already warmup-excluded and marginally charged."""
        monitor.on_observe = self.observe_device

    def observe_device(self, n_items: int, elapsed_s: float) -> None:
        self.stages[STAGE_DEVICE].observe(n_items, elapsed_s)

    def observe_batch(self, uload: int, n_evaluated: int, elapsed_s: float,
                      *, n_cached: Optional[int] = None,
                      warm: bool = True) -> None:
        """One drained batch. ``n_evaluated`` is what the device ran
        (feeds the rate fit); ``n_cached`` — when the caller can name
        the Trust-DB hit count — separates cache reduction from
        deadline shedding, so ``eval_frac`` stays a pure hit-rate model
        and ``predict`` doesn't double-count the shed budget."""
        if not warm:
            self.n_warmup_excluded += 1
            return
        self._uload_total += float(uload)
        self._evaluated_total += (float(uload - n_cached)
                                  if n_cached is not None
                                  else float(n_evaluated))
        self.stages[STAGE_BATCH].observe(n_evaluated, elapsed_s)

    def observe_queue(self, delay_s: float) -> None:
        self.stages[STAGE_QUEUE].observe(1, delay_s)

    def observe_retrieve(self, n_items: int, elapsed_s: float) -> None:
        self.stages[STAGE_RETRIEVE].observe(n_items, elapsed_s)

    def observe_gather(self, elapsed_s: float) -> None:
        self.stages[STAGE_GATHER].observe(1, elapsed_s)

    # -- fitted parameters --------------------------------------------------

    def eval_frac(self) -> float:
        """Fraction of enqueued items that miss the Trust-DB cache and
        are therefore device-eligible. Deadline shedding is NOT folded
        in here — ``predict`` models that itself via the eval budget."""
        if self._uload_total <= 0.0:
            return 1.0
        return min(self._evaluated_total / self._uload_total, 1.0)

    def device_rate_items_per_s(self) -> float:
        """Fitted evaluation rate; falls back to the config-seeded rate
        (the same seed ``LoadMonitor`` uses) when nothing was measured."""
        for stage in (STAGE_BATCH, STAGE_DEVICE):
            r = self.stages[stage].rate_items_per_s
            if r is not None and r > 0.0:
                return r
        return self.cfg.u_capacity / max(self.cfg.deadline_s, 1e-9)

    def fitted(self) -> Dict[str, object]:
        return {
            "drain_mode": self.drain_mode,
            "pipeline_depth": self.pipeline_depth,
            "batch_items": self.batch_items,
            "eval_frac": self.eval_frac(),
            "device_rate_items_per_s": self.device_rate_items_per_s(),
            "n_warmup_excluded": self.n_warmup_excluded,
            "stages": {s: st.summary() for s, st in self.stages.items()},
        }


# ---------------------------------------------------------------------------
# closed queueing-network what-if
# ---------------------------------------------------------------------------

@dataclass
class CapacityPrediction:
    n_replicas: int
    pipeline_depth: int
    batch_items: int
    throughput_items_per_s: float
    p50_s: float
    p99_s: float
    makespan_s: float
    n_requests: int
    n_items: int


def predict(model: ServiceTimeModel, n_replicas: int, pipeline_depth: int,
            batch_items: int,
            workload: Sequence[Tuple[float, int, str]],
            *, round_s: Optional[float] = None) -> CapacityPrediction:
    """What-if: replay ``workload`` through a deterministic queueing
    mini-model of an ``n_replicas`` fleet and predict throughput / p99.

    ``workload`` is the arrival curve: ``(t_arrival, n_items, tenant)``
    rows sorted by time (unsorted input is sorted here).  The mini-model
    mirrors the fleet's actual mechanics — consistent-ring tenant
    routing, one drained batch per replica per ``round_s`` cadence tick,
    batches capped at ``batch_items`` whole requests, the shedder's
    effective-deadline eval budget, cache hits at the fitted
    ``eval_frac``, service charged at the fitted device rate — without
    building a single engine.  Scheduling nuances the model ignores
    (priority classes, stealing, hedging) are second-order for capacity;
    the validation gate in ``bench_capacity`` bounds the error at 25%.
    """
    if n_replicas <= 0:
        raise ValueError("n_replicas must be positive")
    arrivals = sorted(workload, key=lambda a: a[0])
    cfg = model.cfg
    rate = model.device_rate_items_per_s()
    ef = model.eval_frac()
    if round_s is None:
        round_s = batch_items / max(rate, 1e-9)
    # The live shedder reads (Ucapacity, Uthreshold) off its
    # LoadMonitor, which re-derives them from the measured rate and the
    # two deadline windows — mirror that derivation from the fitted
    # rate, NOT the raw config constants, or every deadline budget is
    # computed against parameters the fleet isn't actually running.
    ucap = max(1, int(rate * cfg.deadline_s))
    uthr = max(0, int(rate * (cfg.overload_deadline_s
                              - cfg.deadline_s)))
    chunk = max(int(getattr(cfg, "chunk_size", 1)), 1)

    ring = ConsistentHashRing()
    names = [f"r{i}" for i in range(n_replicas)]
    for name in names:
        ring.add(name, 1.0)

    clock = {name: 0.0 for name in names}
    queues: Dict[str, Deque[Tuple[float, int]]] = {
        name: deque() for name in names}
    latencies: List[float] = []
    completions: List[float] = []
    n_items_total = 0

    def _drain_round() -> bool:
        any_batch = False
        for name in names:
            q = queues[name]
            if not q:
                continue
            batch: List[Tuple[float, int]] = []
            total = 0
            while q and (not batch or total + q[0][1] <= batch_items):
                t_arr, n = q.popleft()
                batch.append((t_arr, n))
                total += n
            dl = effective_deadline(
                total, ucap, uthr,
                deadline_s=cfg.deadline_s,
                overload_deadline_s=cfg.overload_deadline_s,
                weight=cfg.very_heavy_weight)
            n_miss = total * ef
            # The shedder walks the drop queue in evaluator chunks and
            # stops at the last WHOLE chunk inside the deadline budget —
            # floor the budget the same way or every budget-bound batch
            # is over-predicted by a fraction of a chunk.
            budget = float((int(rate * dl) // chunk) * chunk)
            n_eval = min(n_miss, max(budget, min(n_miss, ucap)))
            clock[name] += n_eval / max(rate, 1e-9)
            done = clock[name]
            for t_arr, n in batch:
                latencies.append(max(done - t_arr, 0.0))
                completions.append(done)
            any_batch = True
        return any_batch

    next_drain = round_s
    for t_arr, n, tenant in arrivals:
        name = ring.route(str(tenant))
        clock[name] = max(clock[name], float(t_arr))
        queues[name].append((float(t_arr), int(n)))
        n_items_total += int(n)
        # Catch-up drains fire AFTER the arrival is enqueued and the
        # routed clock has advanced — the trace driver's order. An
        # idle gap between arrivals is charged to whatever was queued
        # through it, exactly as the event-driven replay charges it.
        while next_drain <= t_arr:
            _drain_round()
            next_drain += round_s
    while _drain_round():
        pass

    if not latencies:
        return CapacityPrediction(
            n_replicas=n_replicas, pipeline_depth=pipeline_depth,
            batch_items=batch_items, throughput_items_per_s=0.0,
            p50_s=0.0, p99_s=0.0, makespan_s=0.0, n_requests=0, n_items=0)
    lat = np.asarray(latencies)
    makespan = max(max(completions), arrivals[-1][0]) if completions else 0.0
    return CapacityPrediction(
        n_replicas=n_replicas,
        pipeline_depth=pipeline_depth,
        batch_items=batch_items,
        throughput_items_per_s=n_items_total / max(makespan, 1e-9),
        p50_s=float(np.percentile(lat, 50.0)),
        p99_s=float(np.percentile(lat, 99.0)),
        makespan_s=float(makespan),
        n_requests=len(latencies),
        n_items=n_items_total,
    )


# ---------------------------------------------------------------------------
# feedforward planner
# ---------------------------------------------------------------------------

@dataclass
class ForecastSnapshot:
    t: float
    rate_now_items_per_s: float
    rate_forecast_items_per_s: float
    pressure: float


class ForecastPlanner:
    """Sliding-window NHPP rate estimate + linear extrapolation.

    ``observe_arrival`` taps every admitted enqueue.  The window is
    split into two half-windows; the rate slope between them is
    extrapolated ``warmup_lead_s`` ahead, which is exactly the lead a
    new replica needs so its jit prewarm finishes before the predicted
    breach.  ``forecast_pressure`` converts the predicted item rate to
    a utilization of the fleet's measured service rate (scaled by the
    fitted cache-hit fraction when a ``ServiceTimeModel`` is attached),
    on the same ``[0, 1]``-ish scale the reactive watermark pressure
    uses so the two signals share one set of thresholds.
    """

    def __init__(self, *, warmup_lead_s: float = 0.5, window_s: float = 2.0,
                 min_arrivals: int = 8,
                 model: Optional[ServiceTimeModel] = None):
        if window_s <= 0.0:
            raise ValueError("window_s must be positive")
        self.warmup_lead_s = float(warmup_lead_s)
        self.window_s = float(window_s)
        self.min_arrivals = int(min_arrivals)
        self.model = model
        self._arrivals: Deque[Tuple[float, int]] = deque()
        self.n_observed = 0
        self.last: Optional[ForecastSnapshot] = None

    def observe_arrival(self, t: float, n_items: int) -> None:
        t = float(t)
        self._arrivals.append((t, int(n_items)))
        self.n_observed += 1
        cutoff = t - self.window_s
        while self._arrivals and self._arrivals[0][0] < cutoff:
            self._arrivals.popleft()

    def _window_rate(self, lo: float, hi: float) -> float:
        if hi <= lo:
            return 0.0
        items = sum(n for t, n in self._arrivals if lo < t <= hi)
        return items / (hi - lo)

    def rate_estimate(self, now: float) -> float:
        return self._window_rate(now - self.window_s, now)

    def forecast_rate(self, now: float) -> float:
        """Linear extrapolation ``warmup_lead_s`` past ``now`` from the
        two half-window rates (centered at ``now - 3w/4`` and
        ``now - w/4``)."""
        half = self.window_s / 2.0
        r_old = self._window_rate(now - self.window_s, now - half)
        r_new = self._window_rate(now - half, now)
        slope = (r_new - r_old) / half
        return max(r_new + slope * (self.warmup_lead_s + half / 2.0), 0.0)

    def forecast_pressure(self, now: float, *,
                          rate_items_per_s: float) -> float:
        """Predicted fleet utilization at ``now + warmup_lead_s``
        against the fleet's current aggregate service rate."""
        if self.n_observed < self.min_arrivals or rate_items_per_s <= 0.0:
            return 0.0
        ef = self.model.eval_frac() if self.model is not None else 1.0
        fr = self.forecast_rate(now)
        pressure = min(fr * ef / rate_items_per_s, 4.0)
        self.last = ForecastSnapshot(
            t=float(now), rate_now_items_per_s=self.rate_estimate(now),
            rate_forecast_items_per_s=fr, pressure=pressure)
        return pressure

    def stats(self) -> Dict[str, float]:
        last = self.last
        return {
            "n_observed": self.n_observed,
            "window_s": self.window_s,
            "warmup_lead_s": self.warmup_lead_s,
            "rate_now_items_per_s":
                last.rate_now_items_per_s if last else 0.0,
            "rate_forecast_items_per_s":
                last.rate_forecast_items_per_s if last else 0.0,
            "pressure": last.pressure if last else 0.0,
        }
