"""Adaptive admission watermarks + tenant quotas from fleet load.

PR 1 left ``AdmissionPolicy`` watermarks and tenant token-bucket quotas
as *static* config (a ROADMAP open item). This module closes the loop
the way ``core.adaptive`` closes it on the Very-Heavy extension weight:
observe, aggregate, push new setpoints.

Aggregation: each replica's ``LoadMonitor`` EWMA throughput yields its
(Ucapacity, Uthreshold); the cluster's capacity is their sum (capacity
planning for vertical search: provision per replica, reason per
fleet). Cluster **pressure** is the EWMA-smoothed ratio of fleet queued
items to fleet (Ucapacity + Uthreshold) — 0 is an idle fleet, 1 means
the backlog alone fills every replica's extended-deadline budget.

Control law (proportional, clamped):

* admission watermarks interpolate from each replica's CONFIGURED
  policy (its ``AdmissionPolicy`` at first sight — the idle anchor)
  down to a floor (saturated), so LOW and then NORMAL traffic starts
  shedding *earlier* on every replica as the fleet heats up — before
  queues hit static backpressure — without discarding the operator's
  ``SchedulerConfig`` watermarks;
* per-tenant quotas are re-derived from measured capacity: tenant rate
  on replica ``r`` = ``tenant_capacity_frac * cluster_rate *
  share(r)``, where ``share(r)`` is the replica's fraction of fleet
  throughput — a tenant may consume at most that fraction of the
  *measured* fleet, not of a stale config guess.

Elastic membership (capacity planning for vertical search: provision
replica count to offered load, not only quotas): on top of the
watermark/quota push, :meth:`membership_decision` turns the same
smoothed fleet pressure into a scale-up / scale-down vote the
``ClusterCoordinator`` executes as a runtime join or graceful leave.
The policy compares the fleet EWMA backlog against the summed
per-replica Ucapacity watermarks, with two kinds of hysteresis so
membership never flaps:

* a wide dead band — scale up only above ``scale_up_pressure``, scale
  down only when the SURVIVING fleet (one replica fewer) would still
  sit below ``scale_down_pressure``;
* a cooldown — after any membership change, no further change for
  ``scale_cooldown_ticks`` updates (joins need a tick to absorb load
  before the backlog statistics mean anything).

Both hysteresis knobs are operator-tunable without constructing the
autoscaler by hand: ``TrustIRConfig.autoscale_up_pressure`` (default
0.75 — scale up when smoothed backlog fills 3/4 of the fleet's
extended-deadline budget), ``autoscale_down_pressure`` (default 0.15 —
scale down only when the n-1 fleet would still sit below 15%), and
``autoscale_cooldown_ticks`` (default 2 autoscale updates) thread
through ``ClusterCoordinator``'s default-autoscaler construction. The
defaults keep the dead band wide relative to per-round backlog noise
(0.15 vs 0.75 is a 5x span) so diurnal traffic crosses it slowly and
flash crowds cross it immediately — the asymmetry chaos traces rely
on.

The static single-host behaviour is the degenerate case: one replica,
``update`` never called.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence, Tuple

from repro.scheduling import AdmissionPolicy

from repro.cluster.replica import ReplicaHandle


@dataclass
class ClusterLoadSnapshot:
    """One autoscaler update, for observability and tests."""
    u_capacity: int                  # fleet Ucapacity (sum of replicas)
    u_threshold: int                 # fleet Uthreshold
    rate_items_per_s: float          # fleet EWMA throughput
    queued_items: int                # fleet backlog
    pressure: float                  # smoothed backlog / fleet budget
    low_watermark: float             # as pushed to the FIRST replica
    normal_watermark: float          # (per-replica when anchors differ)
    tenant_rates: Dict[str, float]   # per-replica items/s per tenant

    def as_dict(self) -> Dict:
        return {"u_capacity": self.u_capacity,
                "u_threshold": self.u_threshold,
                "rate_items_per_s": self.rate_items_per_s,
                "queued_items": self.queued_items,
                "pressure": self.pressure,
                "low_watermark": self.low_watermark,
                "normal_watermark": self.normal_watermark,
                "tenant_rates": dict(self.tenant_rates)}


class WatermarkAutoscaler:
    def __init__(self, base_low: float = 0.5, base_normal: float = 0.9,
                 floor_low: float = 0.1, floor_normal: float = 0.5,
                 ewma: float = 0.5, ewma_up: float = 1.0,
                 tenant_capacity_frac: float = 0.5,
                 tenant_burst_s: float = 2.0,
                 scale_up_pressure: float = 0.75,
                 scale_down_pressure: float = 0.15,
                 scale_cooldown_ticks: int = 2):
        if not (0.0 <= floor_low <= base_low <= 1.0):
            raise ValueError("need 0 <= floor_low <= base_low <= 1")
        if not (0.0 <= floor_normal <= base_normal <= 1.0):
            raise ValueError("need 0 <= floor_normal <= base_normal <= 1")
        if not (0.0 <= scale_down_pressure < scale_up_pressure <= 1.0):
            raise ValueError(
                "need 0 <= scale_down_pressure < scale_up_pressure <= 1 "
                "(the dead band IS the anti-flap hysteresis)")
        # Fallback idle anchors, used only when a replica's configured
        # policy cannot be read; normally each replica's own
        # AdmissionPolicy at first sight is the anchor.
        self.base_low = base_low
        self.base_normal = base_normal
        self.floor_low = floor_low
        self.floor_normal = floor_normal
        # Asymmetric smoothing: pressure RISES at ewma_up (default:
        # instantly — a saturated fleet must not look idle for the
        # first few ticks and trigger a cold-start scale-down) and
        # decays at ewma (slow — scale-down is the conservative
        # direction).
        self.ewma = ewma
        self.ewma_up = ewma_up
        # <=0 disables quota pushing (watermarks only).
        self.tenant_capacity_frac = tenant_capacity_frac
        self.tenant_burst_s = tenant_burst_s
        self.scale_up_pressure = scale_up_pressure
        self.scale_down_pressure = scale_down_pressure
        self.scale_cooldown_ticks = int(scale_cooldown_ticks)
        self._pressure = 0.0
        self._anchors: Dict[str, Tuple[float, float]] = {}
        self.n_updates = 0
        self._last_scale_tick = -(10 ** 9)

    @property
    def pressure(self) -> float:
        return self._pressure

    def forget(self, replica_id: str) -> None:
        """Drop a departed replica's watermark anchor (a future replica
        reusing the id re-anchors on ITS configured policy)."""
        self._anchors.pop(replica_id, None)

    # -- elastic membership policy -------------------------------------------
    def membership_decision(self, n_replicas: int, min_replicas: int,
                            max_replicas: int,
                            forecast_pressure: float = None) -> int:
        """Vote on fleet size from the last update's smoothed pressure:
        ``+1`` (join a replica), ``-1`` (gracefully drain one out), or
        ``0``. Call after :meth:`update` each autoscale tick.

        Hysteresis: the up/down thresholds form a dead band, scale-down
        additionally requires the surviving ``n-1`` fleet to stay below
        the down threshold (removing capacity must not immediately push
        pressure toward the up threshold), and any decision starts a
        ``scale_cooldown_ticks``-update cooldown — so consecutive ticks
        can never alternate join/leave on a noisy boundary.

        ``forecast_pressure`` is the feedforward signal (the
        ``ForecastPlanner``'s predicted utilization ``warmup_lead_s``
        ahead, on the same scale as the reactive pressure). It is
        deliberately folded into THIS vote rather than voting on its
        own: a planner-initiated pre-warm join takes the same branch,
        sets the same ``_last_scale_tick``, and therefore consumes the
        same cooldown as a reactive join — reactive and feedforward can
        never produce two membership changes inside one cooldown
        window. The forecast also vetoes scale-down (shedding capacity
        right before a predicted wave is the one unforced error the
        planner exists to prevent).
        """
        if max_replicas <= 0:               # membership fixed
            return 0
        min_replicas = max(min_replicas, 1)
        if self.n_updates - self._last_scale_tick \
                < self.scale_cooldown_ticks:
            return 0
        p = self._pressure
        f = forecast_pressure if forecast_pressure is not None else 0.0
        if max(p, f) >= self.scale_up_pressure \
                and n_replicas < max_replicas:
            self._last_scale_tick = self.n_updates
            return 1
        survivors = max(n_replicas - 1, 1)
        if n_replicas > min_replicas and \
                max(p, f) * n_replicas / survivors \
                <= self.scale_down_pressure:
            self._last_scale_tick = self.n_updates
            return -1
        return 0

    def cluster_parameters(self, replicas: Sequence[ReplicaHandle]
                           ) -> Tuple[int, int, float]:
        """Fleet (Ucapacity, Uthreshold, rate) — per-replica LoadMonitor
        EWMA estimates, summed."""
        ucap = uthr = 0
        rate = 0.0
        for rep in replicas:
            c, t = rep.monitor.parameters()
            ucap += c
            uthr += t
            rate += rep.monitor.rate
        return ucap, uthr, rate

    def update(self, replicas: Sequence[ReplicaHandle],
               tenants: Iterable[str] = ()) -> ClusterLoadSnapshot:
        """Observe fleet load, then push watermarks (every replica) and
        tenant quotas (every replica x tenant) derived from it."""
        ucap, uthr, rate = self.cluster_parameters(replicas)
        queued = sum(rep.queued_items for rep in replicas)
        raw = min(queued / max(ucap + uthr, 1), 1.0)
        alpha = self.ewma_up if raw > self._pressure else self.ewma
        self._pressure = alpha * raw + (1 - alpha) * self._pressure
        p = min(max(self._pressure, 0.0), 1.0)

        tenant_rates: Dict[str, float] = {}
        tenant_list: List[str] = sorted(set(tenants))
        low_wm = self.base_low
        normal_wm = self.base_normal
        for i, rep in enumerate(replicas):
            # Idle anchor = the replica's CONFIGURED policy, captured
            # the first time this autoscaler sees it (the policy object
            # itself is replaced by every update below).
            if rep.replica_id not in self._anchors:
                pol = rep.scheduler.policy
                self._anchors[rep.replica_id] = (
                    pol.low_watermark, pol.normal_watermark)
            base_low, base_normal = self._anchors[rep.replica_id]
            rep_low = min(base_low, self.floor_low) \
                + (base_low - min(base_low, self.floor_low)) * (1.0 - p)
            rep_normal = min(base_normal, self.floor_normal) \
                + (base_normal - min(base_normal, self.floor_normal)) \
                * (1.0 - p)
            if i == 0:                  # reported snapshot values
                low_wm, normal_wm = rep_low, rep_normal
            rep.scheduler.policy = AdmissionPolicy(
                low_watermark=rep_low, normal_watermark=rep_normal)
            if self.tenant_capacity_frac > 0 and tenant_list:
                share = rep.monitor.rate / max(rate, 1e-9)
                t_rate = self.tenant_capacity_frac * rate * share
                for tenant in tenant_list:
                    rep.scheduler.limiter.configure(
                        tenant, rate=t_rate,
                        burst=t_rate * self.tenant_burst_s)
                    tenant_rates[f"{rep.replica_id}:{tenant}"] = t_rate

        self.n_updates += 1
        return ClusterLoadSnapshot(
            u_capacity=ucap, u_threshold=uthr, rate_items_per_s=rate,
            queued_items=queued, pressure=p, low_watermark=low_wm,
            normal_watermark=normal_wm, tenant_rates=tenant_rates)
