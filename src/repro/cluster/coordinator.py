"""The cluster event loop: route -> admit -> steal -> drain -> hedge.

``ClusterCoordinator`` turns N independent ``ReplicaHandle`` stacks
into one serving fleet:

* **route** — tenants map to replicas through the consistent-hash ring
  (``routing``): sticky (per-tenant cache/prior locality), weighted,
  and minimally disturbed by membership changes.
* **admit** — the chosen replica's own scheduler applies the PR-1
  admission ladder against *its* regime; rejections surface through the
  coordinator as the same explicit prior-answered ``Response``.
* **steal** — when one replica's ``PriorityQueueBank`` runs hot while a
  sibling idles, queued work migrates from the *back* of the victim's
  lowest-importance non-empty class (``PriorityQueueBank.steal_back``):
  latest-deadline, least-important requests move, the victim's EDF
  heads never reorder.
* **drain** — micro-batches execute round-robin across replicas, one
  batch per replica per round (fair progress; on simulated clocks the
  replicas genuinely overlap in time).
* **hedge** — requests stuck past the hedge latency are re-dispatched
  to a REAL backup replica (the ring's next distinct replica for the
  tenant) at CRITICAL priority and the twins race; the first completed
  copy wins and the loser is deduplicated fleet-wide by the
  coordinator, so the no-drop invariant stays "exactly one Response
  per request" across the fleet. Re-hedging (a backup that is itself
  overloaded) is allowed up to ``max_hedges``, all of it token-bucket
  capped at a fraction of admitted traffic (``HedgedDispatch``).

Closing the loop, a ``WatermarkAutoscaler`` periodically aggregates
per-replica ``LoadMonitor`` EWMA rates into fleet (Ucapacity,
Uthreshold) and pushes adaptive admission watermarks + tenant quotas
back onto every replica.

``TrustIRConfig.n_replicas = 1`` is the degenerate case: one replica,
no stealing, hedging disabled (no backup exists) — behaviour identical
to a bare ``ServingEngine``.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import TrustIRConfig
from repro.distribution.fault_tolerance import HedgedDispatch
from repro.scheduling import Priority, Response, SchedulerConfig
from repro.serving.engine import slo_stats_of

from repro.cluster.autoscale_watermarks import (ClusterLoadSnapshot,
                                                WatermarkAutoscaler)
from repro.cluster.replica import ReplicaHandle
from repro.cluster.routing import ConsistentHashRing


@dataclass
class ClusterConfig:
    """Fleet-level policy knobs (per-replica policy stays in
    ``SchedulerConfig``)."""
    steal_threshold_items: int = 1      # min queued-item imbalance
    max_steals_per_round: int = 8
    hedge_after_s: float = 0.0          # 0 disables cluster hedging
    max_hedges: int = 1                 # re-dispatches per request
    hedge_budget_frac: float = 0.05     # hedge tokens per admitted req
    autoscale: bool = False             # adaptive watermarks + quotas
    autoscale_every: int = 4            # drain rounds between updates
    vnodes_per_weight: int = 64


@dataclass
class ClusterStats:
    n_enqueued: int = 0
    n_steals: int = 0
    n_hedges: int = 0                   # cross-replica re-dispatches
    n_twin_drops: int = 0               # hedge losers deduplicated
    n_drain_rounds: int = 0

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class ClusterCoordinator:
    def __init__(self, cfg: TrustIRConfig, evaluate_chunk: Callable,
                 cluster_cfg: Optional[ClusterConfig] = None,
                 sched_cfg: Optional[SchedulerConfig] = None,
                 sim_rate_items_per_s: Optional[float] = None,
                 autoscaler: Optional[WatermarkAutoscaler] = None,
                 kv_pools: Optional[List] = None,
                 drain_mode: Optional[str] = None,
                 evaluate_batch: Optional[Callable] = None):
        self.cfg = cfg
        self.cluster_cfg = cluster_cfg or ClusterConfig()
        n = max(1, int(cfg.n_replicas))
        weights = (tuple(cfg.replica_weights) if cfg.replica_weights
                   else (1.0,) * n)
        if len(weights) != n:
            raise ValueError(
                f"replica_weights has {len(weights)} entries for "
                f"n_replicas={n}")

        cc = self.cluster_cfg
        hedging = cc.hedge_after_s > 0 and n > 1
        self.hedge = (HedgedDispatch(cc.hedge_after_s,
                                     max_hedges=cc.max_hedges,
                                     budget_frac=cc.hedge_budget_frac)
                      if hedging else None)
        base_sched = sched_cfg or SchedulerConfig()
        if hedging:
            # The cluster owns hedging (twins race REAL replicas);
            # engine-internal same-queue hedging would double-dispatch.
            base_sched = dataclasses.replace(base_sched,
                                             hedge_after_s=0.0)

        self._ids = itertools.count()   # fleet-unique request ids
        self.ring = ConsistentHashRing(cc.vnodes_per_weight)
        self.replicas: List[ReplicaHandle] = []
        for i, w in enumerate(weights):
            rid = f"r{i}"
            self.replicas.append(ReplicaHandle(
                rid, cfg, evaluate_chunk, weight=w,
                sched_cfg=base_sched,
                sim_rate_items_per_s=sim_rate_items_per_s,
                kv_pool=(kv_pools[i] if kv_pools else None),
                request_ids=self._ids,
                drain_mode=drain_mode,
                evaluate_batch=evaluate_batch))
            self.ring.add(rid, w)
        self.by_id: Dict[str, ReplicaHandle] = {
            r.replica_id: r for r in self.replicas}

        self.autoscaler = autoscaler or (WatermarkAutoscaler()
                                         if cc.autoscale else None)
        self.last_snapshot: Optional[ClusterLoadSnapshot] = None
        self.tenants_seen: set = set()
        self.stats = ClusterStats()
        self.completed: List[Response] = []
        self._responded: set = set()    # fleet-wide answered rids

    # -- fleet views ---------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def queued_items(self) -> int:
        return sum(r.queued_items for r in self.replicas)

    @property
    def max_batch_items(self) -> int:
        return self.replicas[0].scheduler.max_batch_items

    def makespan_s(self) -> float:
        """Latest replica clock (simulated fleets): total time the fleet
        needed — replicas run in parallel, so the slowest one bounds
        throughput."""
        return max((r.clock.t for r in self.replicas
                    if r.clock is not None), default=0.0)

    # -- route + admit -------------------------------------------------------
    def route(self, tenant: str) -> ReplicaHandle:
        return self.by_id[self.ring.route(tenant)]

    def enqueue(self, item_keys: np.ndarray, buckets: np.ndarray,
                features: Dict[str, np.ndarray],
                slo_s: Optional[float] = None,
                priority: Priority = Priority.NORMAL,
                tenant: str = "default",
                needs_kv_slot: bool = False,
                t_arrival: Optional[float] = None) -> int:
        """Route by tenant, then admit on that replica. Returns the
        fleet-unique request id; a rejection completes immediately into
        ``self.completed``."""
        rep = self.route(tenant)
        if t_arrival is not None:
            rep.advance_to(t_arrival)
        self.tenants_seen.add(tenant)
        n_before = len(rep.engine.completed)
        rid = rep.engine.enqueue(item_keys, buckets, features,
                                 slo_s=slo_s, priority=priority,
                                 tenant=tenant,
                                 needs_kv_slot=needs_kv_slot)
        self.stats.n_enqueued += 1
        # A rejection completes immediately; only ADMITTED traffic
        # earns hedge budget (rejected floods must not raise the cap).
        if self.hedge is not None \
                and len(rep.engine.completed) == n_before:
            self.hedge.note_request()
        self._collect()                 # surface immediate rejections
        return rid

    # -- steal ---------------------------------------------------------------
    def _steal_rebalance(self) -> None:
        """Migrate work from the hottest bank to the idlest while the
        imbalance exceeds the threshold. Steals come off the BACK of the
        victim's lowest-importance non-empty class and a class is never
        robbed below 2 entries, so every EDF head stays put."""
        if self.n_replicas < 2:
            return
        for _ in range(self.cluster_cfg.max_steals_per_round):
            by_load = sorted(self.replicas,
                             key=lambda r: (r.queued_items,
                                            r.replica_id))
            idle, hot = by_load[0], by_load[-1]
            gap = hot.queued_items - idle.queued_items
            if gap < self.cluster_cfg.steal_threshold_items:
                break
            qreq = hot.bank.steal_back()
            if qreq is None:            # nothing stealable (heads only)
                break
            if qreq.n_items >= gap:
                # Moving it would leave the gap as large or larger
                # (just inverted) — the same jumbo request would be
                # stolen straight back next iteration. Undo and stop.
                hot.bank.push(qreq)
                break
            # The request has been queued (hence stealable) since its
            # enqueue time — the victim's clock being further ahead only
            # means the victim already worked deep into ITS backlog.
            idle.advance_to(qreq.enqueue_t)
            if not idle.bank.push(qreq):
                hot.bank.push(qreq)     # thief full: undo, stop trying
                break
            self.stats.n_steals += 1

    # -- hedge ---------------------------------------------------------------
    def _backup_for(self, tenant: str, current: ReplicaHandle,
                    n_prior_hedges: int = 0
                    ) -> Optional[ReplicaHandle]:
        """Hedge target for the ``n_prior_hedges + 1``-th dispatch of a
        ``tenant`` request waiting on ``current``.

        The k-th hedge walks to the k-th distinct ring replica past the
        primary, so a RE-hedge (the backup is itself overloaded)
        escalates to a replica that does not already hold a copy
        instead of bouncing between the primary/backup pair. Skips
        ``current`` (a stolen copy may sit off its chain position);
        None once the chain is exhausted — every replica has a copy."""
        chain = self.ring.route_chain(tenant, self.n_replicas)
        for rid in chain[n_prior_hedges + 1:]:
            if rid != current.replica_id:
                return self.by_id[rid]
        return None

    def _hedge_scan(self) -> None:
        """Re-dispatch requests stuck past the hedge latency onto a real
        backup replica at CRITICAL priority. Twins race; ``_collect``
        keeps the first completion and drops the loser."""
        if self.hedge is None or self.hedge.budget_available < 1.0:
            return          # tokens only refill on enqueue, not mid-scan
        for rep in self.replicas:
            now = rep.now()
            for p in Priority:
                for qreq in rep.bank.queues[p].entries():
                    if not self.hedge.should_hedge(
                            now - qreq.hedge_wait_base_t,
                            qreq.n_hedges):
                        continue
                    backup = self._backup_for(qreq.tenant, rep,
                                              qreq.n_hedges)
                    if backup is None:      # every replica has a copy
                        continue
                    # In continuous time the hedge fires the moment the
                    # wait (since the last dispatch) crosses the hedge
                    # latency.
                    fire_t = qreq.hedge_wait_base_t \
                        + self.hedge.hedge_after_s
                    backup.advance_to(fire_t)
                    if qreq.dispatch_twin(
                            backup.bank.queues[Priority.CRITICAL].push,
                            fire_t):
                        self.hedge.record_hedge()
                        self.stats.n_hedges += 1

    # -- drain ---------------------------------------------------------------
    def drain(self, max_rounds: Optional[int] = None) -> List[Response]:
        """Round-robin drain: steal + hedge scans, then one micro-batch
        per replica, until every bank is empty (or ``max_rounds``).
        Returns the NEW responses produced (deduplicated)."""
        produced: List[Response] = []
        rounds = 0
        while max_rounds is None or rounds < max_rounds:
            self._steal_rebalance()
            self._hedge_scan()
            any_batch = False
            for rep in self.replicas:
                before = rep.scheduler.stats.n_batches
                rep.engine.drain(max_batches=1)
                any_batch |= rep.scheduler.stats.n_batches > before
            produced.extend(self._collect())
            rounds += 1
            self.stats.n_drain_rounds += 1
            if self.autoscaler is not None and \
                    self.stats.n_drain_rounds \
                    % max(self.cluster_cfg.autoscale_every, 1) == 0:
                self.last_snapshot = self.autoscaler.update(
                    self.replicas, self.tenants_seen)
            if not any_batch:
                break
        return produced

    def _collect(self) -> List[Response]:
        """Pull new responses off every replica, keeping the FIRST
        completion per request id (hedge losers are dropped here — the
        fleet-wide dedup).

        When both twins complete within the same collection window,
        "first" is decided by completion time — twins share an arrival,
        so lower latency IS earlier completion — not by replica scan
        order (the hedge exists precisely because the primary is slow,
        and scan order would keep the loser)."""
        window: List[Response] = []
        for rep in self.replicas:
            comp = rep.engine.completed
            while rep.n_collected < len(comp):
                window.append(comp[rep.n_collected])
                rep.n_collected += 1
        by_rid: Dict[int, Response] = {}
        order: List[int] = []
        for resp in window:
            rid = resp.request_id
            if rid in self._responded:      # twin answered last window
                self.stats.n_twin_drops += 1
                continue
            if rid in by_rid:               # both twins in this window
                self.stats.n_twin_drops += 1
                if resp.latency_s < by_rid[rid].latency_s:
                    by_rid[rid] = resp
                continue
            by_rid[rid] = resp
            order.append(rid)
        fresh = [by_rid[rid] for rid in order]
        for resp in fresh:
            self._responded.add(resp.request_id)
            self.completed.append(resp)
        return fresh

    # -- observability -------------------------------------------------------
    def slo_stats(self) -> Dict[str, float]:
        return slo_stats_of(self.completed)

    def scheduler_stats(self) -> Dict:
        """Fleet aggregate in the single-engine stats shape (drivers and
        reports consume both interchangeably), plus cluster extras."""
        agg: Dict = {"n_submitted": 0, "n_admitted": 0, "n_rejected": 0,
                     "rejected_by_reason": {}, "n_batches": 0,
                     "n_batched_items": 0, "n_hedges": 0}
        per_replica: Dict[str, Dict] = {}
        for rep in self.replicas:
            s = rep.scheduler.stats.as_dict()
            per_replica[rep.replica_id] = s
            for k in ("n_submitted", "n_admitted", "n_rejected",
                      "n_batches", "n_batched_items", "n_hedges"):
                agg[k] += s[k]
            for reason, c in s["rejected_by_reason"].items():
                agg["rejected_by_reason"][reason] = \
                    agg["rejected_by_reason"].get(reason, 0) + c
        agg["n_hedges"] += self.stats.n_hedges
        agg["mean_batch_fill"] = (agg["n_batched_items"]
                                  / max(agg["n_batches"], 1))
        agg["cluster"] = self.stats.as_dict()
        agg["per_replica"] = per_replica
        if self.last_snapshot is not None:
            agg["autoscale"] = self.last_snapshot.as_dict()
        return agg
