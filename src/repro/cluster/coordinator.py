"""The cluster event loop: route -> admit -> steal -> drain -> hedge.

``ClusterCoordinator`` turns N independent ``ReplicaHandle`` stacks
into one serving fleet:

* **route** — tenants map to replicas through the consistent-hash ring
  (``routing``): sticky (per-tenant cache/prior locality), weighted,
  and minimally disturbed by membership changes.
* **admit** — the chosen replica's own scheduler applies the PR-1
  admission ladder against *its* regime; rejections surface through the
  coordinator as the same explicit prior-answered ``Response``.
* **steal** — when one replica's ``PriorityQueueBank`` runs hot while a
  sibling idles, queued work migrates out of the victim's
  lowest-importance non-empty class (``PriorityQueueBank.steal_back``):
  cost-aware by default (``ClusterConfig.cost_aware_steal``), the
  non-head entry with the highest estimated eval cost on the victim —
  items x Trust-DB miss probability (``ReplicaHandle.steal_cost``) —
  moves, so cache-cold work migrates while cache-hot work stays where
  its cache is warm; the victim's EDF heads never reorder.
* **drain** — micro-batches execute round-robin across replicas, one
  batch per replica per round (fair progress; on simulated clocks the
  replicas genuinely overlap in time). Each replica keeps ONE
  ``DrainExecutor`` window alive ACROSS rounds (``pipeline_depth >=
  2``, wall clocks): its fused device steps overlap the next round's
  scans and batch formation, and every round begins by POLLING the
  completed in-flight batches so the steal/hedge/autoscale decisions
  below read stats as fresh as the hardware allows — not one batch
  late.
* **hedge** — requests stuck past the hedge latency are re-dispatched
  to a REAL backup replica (the ring's next distinct replica for the
  tenant) at CRITICAL priority and the twins race; the first completed
  copy wins and the loser is deduplicated fleet-wide by the
  coordinator, so the no-drop invariant stays "exactly one Response
  per request" across the fleet. Re-hedging (a backup that is itself
  overloaded) is allowed up to ``max_hedges``, all of it token-bucket
  capped at a fraction of admitted traffic (``HedgedDispatch``).

Closing the loop, a ``WatermarkAutoscaler`` periodically aggregates
per-replica ``LoadMonitor`` EWMA rates into fleet (Ucapacity,
Uthreshold) and pushes adaptive admission watermarks + tenant quotas
back onto every replica.

**Elastic membership** (runtime join/leave/crash):

* ``add_replica`` joins a fresh (or caller-built) replica at the
  fleet's current simulated time; the ring rebalances minimally, so
  only the tenants the new replica claims move.
* ``remove_replica(rid, drain=True)`` is the graceful leave: the
  replica is *fenced* from routing first, then its queued backlog
  hands off to the ring's new owners in drain order (strict priority,
  EDF within class — no surviving EDF head reorders), with hedge twins
  deduplicated across the handoff (a copy whose twin is already queued
  on a surviving replica is dropped, not double-served).
* ``remove_replica(rid, drain=False)`` is a crash: the replica's
  engine state (queues, cache, prior) is lost wholesale. The
  coordinator recovers from its **admission journal** — every admitted
  request is journaled until its response lands — by re-dispatching
  each unanswered request that has no live copy on a surviving replica
  to the ring's new owner. The fleet-wide no-drop invariant survives
  both paths.
* With ``ClusterConfig.max_replicas > 0`` the autoscaler's
  ``membership_decision`` (fleet pressure vs per-replica capacity
  watermarks, hysteresis + cooldown) drives joins and graceful leaves
  from inside the drain loop instead of only pushing quotas.

**Trust-DB gossip** (``ClusterConfig.gossip``): replicas tap their
shedder's fresh evaluations (cache fills); once per drain round the
coordinator harvests the ``(url_key, trust)`` deltas, publishes them to
a bounded-budget ``TrustGossipBus``, and broadcasts the freshest to
every sibling's Trust-DB — so a hot URL flooding every tenant is
evaluated once fleet-wide instead of once per replica. The coordinator
also counts fleet-wide duplicate evaluations (the same key freshly
evaluated on more than one replica) whether or not gossip is on, which
is the benchmark's measured quantity.

``TrustIRConfig.n_replicas = 1`` is the degenerate case: one replica,
no stealing, hedging disabled (no backup exists) — behaviour identical
to a bare ``ServingEngine``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import TrustIRConfig
from repro.distribution.fault_tolerance import HedgedDispatch
from repro.fanout import (FanoutSearcher, ReplicationPolicy,
                          StripeReplicator, mirror_shard_of)
from repro.scheduling import (Priority, QueuedRequest, Request, Response,
                              SchedulerConfig)
from repro.scheduling.priorities import REASON_QUEUE_FULL
from repro.serving.engine import slo_stats_of

from repro.cluster.autoscale_watermarks import (ClusterLoadSnapshot,
                                                WatermarkAutoscaler)
from repro.cluster.capacity import ForecastPlanner, ServiceTimeModel
from repro.cluster.gossip import TrustGossipBus
from repro.cluster.loadindex import ReplicaLoadHeap
from repro.cluster.replica import ReplicaHandle
from repro.cluster.routing import ConsistentHashRing


@dataclass
class ClusterConfig:
    """Fleet-level policy knobs (per-replica policy stays in
    ``SchedulerConfig``)."""
    steal_threshold_items: int = 1      # min queued-item imbalance
    max_steals_per_round: int = 8
    hedge_after_s: float = 0.0          # 0 disables cluster hedging
    max_hedges: int = 1                 # re-dispatches per request
    hedge_budget_frac: float = 0.05     # hedge tokens per admitted req
    autoscale: bool = False             # adaptive watermarks + quotas
    autoscale_every: int = 4            # drain rounds between updates
    vnodes_per_weight: int = 64
    # Elastic membership: with max_replicas > 0 (and autoscale on) the
    # autoscaler's membership_decision drives runtime joins/graceful
    # leaves between min_replicas and max_replicas; 0 = fixed fleet.
    min_replicas: int = 0
    max_replicas: int = 0
    # Cross-replica Trust-DB gossip (cache-fill delta broadcast on a
    # bounded per-round budget). "broadcast" delivers every kept delta
    # to every sibling (O(n^2) messages/round); "epidemic" pushes each
    # delta to ceil(log2 n) sampled peers with a per-round
    # anti-entropy pull — O(n log n), the 48+ replica mode.
    gossip: bool = False
    gossip_budget_items: int = 256
    gossip_mode: str = "broadcast"
    # Warm Trust-DB handoff on graceful leave: the leaving replica's
    # top-K freshest (url, trust) cache entries ship to the ring's new
    # owners via apply_trust_deltas (0 disables — the cache then
    # re-warms purely through gossip / duplicate evaluations).
    warm_handoff_top_k: int = 1024
    # Cost-aware stealing: rank steal candidates by estimated eval
    # cost on the victim (items x Trust-DB miss probability), so
    # cache-cold work migrates and cache-hot work stays warm.
    cost_aware_steal: bool = True
    # Feedforward capacity planning (repro.cluster.capacity): forecast
    # the arrival curve, feed predicted utilization into the
    # autoscaler's membership vote, and jit-prewarm planner-initiated
    # joins at production shapes before the ring routes to them.
    forecast: bool = False
    warmup_lead_s: float = 0.5
    forecast_window_s: float = 2.0


@dataclass
class ClusterStats:
    n_enqueued: int = 0
    n_steals: int = 0
    n_hedges: int = 0                   # cross-replica re-dispatches
    n_twin_drops: int = 0               # hedge losers deduplicated
    n_drain_rounds: int = 0
    # elastic membership
    n_joins: int = 0
    n_leaves: int = 0                   # graceful (drain-and-handoff)
    n_crashes: int = 0
    n_handoffs: int = 0                 # requests migrated on leave
    n_handoff_twin_drops: int = 0       # hedge twins deduped at handoff
    n_warm_handoff_entries: int = 0     # (url, trust) pairs shipped on
                                        # a graceful leave (warm cache)
    n_crash_recovered: int = 0          # journal-replayed after a crash
    # doc-partitioned retrieval shards (repro.retrieval)
    n_partition_moves: int = 0          # stripes handed off (join/leave)
    n_partition_rebuilds: int = 0       # stripes re-indexed after crash
    # tail-tolerant fan-out (repro.fanout)
    n_stripe_replications: int = 0      # slow shards mirrored to a sib
    n_mirror_drops: int = 0             # mirrors dropped on recovery
    # coordinated rolling restarts
    n_restarts: int = 0                 # replicas restarted in place
    n_restart_waves: int = 0            # ring-disjoint waves executed
    # fleet-wide evaluation accounting (gossip's measured quantity)
    n_eval_items: int = 0               # fresh evaluations, fleet-wide
    n_duplicate_evals: int = 0          # same key evaluated again
    # feedforward capacity planning (repro.cluster.capacity)
    n_prewarm_joins: int = 0            # joins primed before unfencing
    n_cold_joins: int = 0               # prewarmed joins whose FIRST
                                        # real batch still hit a fresh
                                        # jit shape (should stay 0)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclass
class _JournalEntry:
    """Admission journal record: everything needed to re-dispatch an
    admitted request after its replica crashes (the WAL a multi-host
    control plane would keep)."""
    item_keys: np.ndarray
    buckets: np.ndarray
    features: Dict[str, np.ndarray]
    arrival_s: float
    slo_s: float
    priority: Priority
    tenant: str
    needs_kv_slot: bool


class ClusterCoordinator:
    def __init__(self, cfg: TrustIRConfig, evaluate_chunk: Callable,
                 cluster_cfg: Optional[ClusterConfig] = None,
                 sched_cfg: Optional[SchedulerConfig] = None,
                 sim_rate_items_per_s: Optional[float] = None,
                 autoscaler: Optional[WatermarkAutoscaler] = None,
                 kv_pools: Optional[List] = None,
                 drain_mode: Optional[str] = None,
                 evaluate_batch: Optional[Callable] = None,
                 retrieval=None,
                 fanout_model=None, feature_sharding=None):
        """``retrieval`` (a ``repro.retrieval.CorpusRetrieval``)
        attaches the sharded inverted-index front end: doc-partition
        stripes route through THIS ring under ``"docpart:p"`` keys,
        each replica's shard is built from the stripes it owns, and
        :meth:`enqueue_query` accepts raw query strings.

        ``fanout_model`` (a ``repro.fanout.ShardServiceModel``) — or
        any of ``cfg.fanout_quorum_k`` / ``cfg.fanout_hedge_after_s``
        — upgrades the fleet searcher to the tail-tolerant
        :class:`FanoutSearcher`: first-k-of-n quorum gather, per-shard
        hedges onto mirror stripes (charged to the cluster hedge
        budget when cluster hedging is on), and EWMA-driven selective
        stripe replication run from the drain loop."""
        self.cfg = cfg
        if cluster_cfg is None:
            # Bare coordinators inherit the system config's elastic
            # membership bounds and gossip switch; an explicit
            # ClusterConfig is authoritative. Elastic bounds imply the
            # autoscaler (membership_decision is its vote).
            cluster_cfg = ClusterConfig(
                min_replicas=getattr(cfg, "min_replicas", 0),
                max_replicas=getattr(cfg, "max_replicas", 0),
                autoscale=getattr(cfg, "max_replicas", 0) > 0,
                gossip=getattr(cfg, "gossip", False),
                gossip_mode=getattr(cfg, "gossip_mode", "broadcast"),
                forecast=getattr(cfg, "forecast", False),
                warmup_lead_s=getattr(cfg, "warmup_lead_s", 0.5),
                forecast_window_s=getattr(cfg, "forecast_window_s", 2.0))
        self.cluster_cfg = cluster_cfg
        n = max(1, int(cfg.n_replicas))
        weights = (tuple(cfg.replica_weights) if cfg.replica_weights
                   else (1.0,) * n)
        if len(weights) != n:
            raise ValueError(
                f"replica_weights has {len(weights)} entries for "
                f"n_replicas={n}")

        cc = self.cluster_cfg
        if cc.max_replicas > 0 and \
                max(cc.min_replicas, 1) > cc.max_replicas:
            raise ValueError("min_replicas exceeds max_replicas")
        hedging = cc.hedge_after_s > 0 and n > 1
        self.hedge = (HedgedDispatch(cc.hedge_after_s,
                                     max_hedges=cc.max_hedges,
                                     budget_frac=cc.hedge_budget_frac)
                      if hedging else None)
        base_sched = sched_cfg or SchedulerConfig()
        if cc.hedge_after_s > 0 and (n > 1 or cc.max_replicas > 0):
            # The cluster owns hedging (twins race REAL replicas);
            # engine-internal same-queue hedging would double-dispatch.
            # Zeroed even at n == 1 when the fleet is ELASTIC: a backup
            # can join at runtime, and the engines' config cannot
            # change then. A permanently single-replica fleet keeps its
            # engine-internal hedging.
            base_sched = dataclasses.replace(base_sched,
                                             hedge_after_s=0.0)

        self._ids = itertools.count()   # fleet-unique request ids
        # Factory state for replicas joined at runtime (add_replica).
        self._base_sched = base_sched
        self._evaluate_chunk = evaluate_chunk
        self._sim_rate = sim_rate_items_per_s
        self._drain_mode = drain_mode
        self._evaluate_batch = evaluate_batch
        self._feature_sharding = feature_sharding
        self._replica_seq = itertools.count(n)

        self.ring = ConsistentHashRing(cc.vnodes_per_weight)
        self.replicas: List[ReplicaHandle] = []
        for i, w in enumerate(weights):
            rid = f"r{i}"
            self.replicas.append(ReplicaHandle(
                rid, cfg, evaluate_chunk, weight=w,
                sched_cfg=base_sched,
                sim_rate_items_per_s=sim_rate_items_per_s,
                kv_pool=(kv_pools[i] if kv_pools else None),
                request_ids=self._ids,
                drain_mode=drain_mode,
                evaluate_batch=evaluate_batch,
                feature_sharding=feature_sharding))
            self.ring.add(rid, w)
        self.by_id: Dict[str, ReplicaHandle] = {
            r.replica_id: r for r in self.replicas}

        # Default autoscaler construction threads the hysteresis knobs
        # through TrustIRConfig (autoscale_*: documented defaults match
        # the previously hard-coded values) so chaos traces can
        # exercise tight vs loose dead-band/cooldown without a
        # hand-built autoscaler.
        self.autoscaler = autoscaler or (WatermarkAutoscaler(
            scale_up_pressure=getattr(cfg, "autoscale_up_pressure",
                                      0.75),
            scale_down_pressure=getattr(cfg, "autoscale_down_pressure",
                                        0.15),
            scale_cooldown_ticks=getattr(cfg, "autoscale_cooldown_ticks",
                                         2))
            if cc.autoscale else None)
        self.gossip = (TrustGossipBus(cc.gossip_budget_items,
                                      mode=cc.gossip_mode)
                       if cc.gossip else None)
        # Capacity planning: the ServiceTimeModel is always on (its
        # taps are O(1) appends on paths that already fire) so any run
        # — reactive or feedforward — yields a fit the what-if
        # `capacity.predict` can consume. The ForecastPlanner (and with
        # it pre-warmed, feedforward-voted joins) only activates with
        # cc.forecast.
        self.capacity = ServiceTimeModel(
            cfg,
            drain_mode=(drain_mode or getattr(cfg, "drain_mode", "host")),
            pipeline_depth=getattr(cfg, "pipeline_depth", 1),
            batch_items=self.max_batch_items)
        self.planner = (ForecastPlanner(
            warmup_lead_s=cc.warmup_lead_s,
            window_s=cc.forecast_window_s,
            model=self.capacity) if cc.forecast else None)
        # (t, replica_id, forecast_pressure) per planner-initiated join
        # — surfaced through scheduler_stats()["forecast"]["log"] and
        # merged into chaos churn timelines by the trace driver.
        self.planner_log: List[Dict] = []
        # Feature schema of live traffic (leaf trailing-shapes+dtypes),
        # captured at first enqueue: what a prewarm batch must look
        # like for the jit signatures to match production.
        self._feature_schema: Optional[Dict] = None
        # replica_id -> warmup-exclusion count right after its prewarm;
        # consumed when its first real batch lands (cold-join gate).
        self._prewarm_watch: Dict[str, int] = {}
        for rep in self.replicas:
            self._attach_capacity(rep)
        self.last_snapshot: Optional[ClusterLoadSnapshot] = None
        self.tenants_seen: set = set()
        # Latest arrival timestamp observed: the fleet's notion of
        # "now" for membership events (a busy replica's clock runs
        # AHEAD of now while it chews backlog, so makespan is not it).
        self._now_hint = 0.0
        self.stats = ClusterStats()
        self.completed: List[Response] = []
        self._responded: set = set()    # fleet-wide answered rids
        # Admission journal: rid -> replayable record, cleared when the
        # response lands (crash recovery reads it; see remove_replica).
        self._journal: Dict[int, _JournalEntry] = {}
        # Final scheduler stats of departed replicas: fleet-lifetime
        # counters (submissions, batches, rejections) must survive
        # membership churn — the control plane scrapes them
        # continuously, so a leave/crash does not erase history.
        self._departed_sched: Dict[str, Dict] = {}
        # Pre-restart scheduler counters of LIVE replicas (a rolling
        # restart rebuilds the engine, zeroing its stats, but the id
        # stays in the fleet — the lifetime aggregate must not dip).
        self._restart_sched_base: Dict[str, Dict] = {}
        # While a rolling restart executes, the autoscaler's membership
        # vote is suppressed: restart waves must not race joins/leaves.
        self._restart_hold = False
        # key -> fleet-wide fresh-evaluation count (duplicate-eval
        # accounting: the quantity gossip exists to reduce).
        self._eval_counts: Dict[int, int] = {}
        # Retrieval front end: build each replica's shard from the
        # doc-partition stripes the ring assigns it, then point every
        # engine at ONE fleet searcher (queries scatter-gather across
        # all live shards; ownership governs residency + handoff).
        self.retrieval = retrieval
        self.searcher = None
        self._part_owner: Dict[int, str] = {}
        if retrieval is not None:
            for rep in self.replicas:
                owned = [p for p in range(retrieval.n_partitions)
                         if self.ring.route(retrieval.partition_key(p))
                         == rep.replica_id]
                rep.shard = retrieval.build_shard(owned)
                for p in owned:
                    self._part_owner[p] = rep.replica_id
            fan_on = (fanout_model is not None
                      or getattr(cfg, "fanout_quorum_k", 0) > 0
                      or getattr(cfg, "fanout_hedge_after_s", 0.0) > 0)
            if fan_on:
                probe_after = getattr(cfg, "fanout_hedge_after_s", 0.0)
                # With cluster hedging on, shard-probe hedges spend the
                # SAME fleet bucket as whole-request twins (their own,
                # shorter fuse; budget refills from admitted traffic).
                # Otherwise the searcher owns a probe-granularity
                # bucket and earns per probe dispatched.
                fan_hedge = (self.hedge.probe_view(probe_after)
                             if probe_after > 0 and self.hedge is not None
                             else None)
                self.searcher = FanoutSearcher(
                    retrieval.corpus,
                    feature_fn=retrieval.feature_fn,
                    quorum_k=getattr(cfg, "fanout_quorum_k", 0),
                    service_model=fanout_model,
                    hedge=fan_hedge,
                    hedge_after_s=probe_after,
                    replicator=StripeReplicator(ReplicationPolicy(
                        slow_factor=getattr(cfg, "fanout_slow_factor",
                                            2.5),
                        recover_factor=getattr(
                            cfg, "fanout_recover_factor", 1.4),
                        max_mirrors=getattr(cfg, "fanout_max_mirrors",
                                            2))))
            else:
                self.searcher = retrieval.searcher([])
            self._attach_searcher()

    # -- fleet views ---------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def queued_items(self) -> int:
        return sum(r.queued_items for r in self.replicas)

    @property
    def max_batch_items(self) -> int:
        return self.replicas[0].scheduler.max_batch_items

    def makespan_s(self) -> float:
        """Latest replica clock (simulated fleets): total time the fleet
        needed — replicas run in parallel, so the slowest one bounds
        throughput."""
        return max((r.clock.t for r in self.replicas
                    if r.clock is not None), default=0.0)

    # -- capacity-model taps -------------------------------------------------
    def _attach_capacity(self, rep: ReplicaHandle) -> None:
        """Wire one replica's measurement taps into the fleet
        ServiceTimeModel. Re-run after a restart — the rebuilt engine
        carries a fresh monitor and shedder."""
        rep.monitor.on_observe = self.capacity.observe_device
        rep.stats_tap = self._capacity_shed_tap
        # Adaptive pipeline depth: the coordinator sets depth per
        # replica through each scheduler's DepthController — point its
        # latency signal at the fleet's per-stage fits so every replica
        # shallows/deepens off the same queue-delay model the capacity
        # planner maintains (local queue-delay EWMAs take over once the
        # replica has landed responses of its own).
        ctrl = getattr(rep.scheduler, "depth_controller", None)
        if ctrl is not None:
            ctrl.model = self.capacity

    def _capacity_shed_tap(self, result, warm: bool) -> None:
        self.capacity.observe_batch(result.uload, result.n_evaluated,
                                    result.response_time_s,
                                    n_cached=result.n_cached, warm=warm)

    # -- route + admit -------------------------------------------------------
    def route(self, tenant: str) -> ReplicaHandle:
        return self.by_id[self.ring.route(tenant)]

    def enqueue(self, item_keys: np.ndarray, buckets: np.ndarray,
                features: Dict[str, np.ndarray],
                slo_s: Optional[float] = None,
                priority: Priority = Priority.NORMAL,
                tenant: str = "default",
                needs_kv_slot: bool = False,
                t_arrival: Optional[float] = None) -> int:
        """Route by tenant, then admit on that replica. Returns the
        fleet-unique request id; a rejection completes immediately into
        ``self.completed``."""
        rep = self.route(tenant)
        if t_arrival is not None:
            rep.advance_to(t_arrival)
        self.tenants_seen.add(tenant)
        n_before = len(rep.engine.completed)
        arrival = rep.now()             # what the engine will stamp
        self._now_hint = max(self._now_hint,
                             t_arrival if t_arrival is not None
                             else arrival)
        if self.planner is not None:
            self.planner.observe_arrival(
                t_arrival if t_arrival is not None else arrival,
                len(item_keys))
        if self._feature_schema is None:
            # Remember what a work batch looks like, so a prewarm pass
            # can jit-compile the exact serving shapes later.
            self._feature_schema = {
                k: (tuple(np.asarray(v).shape[1:]),
                    str(np.asarray(v).dtype))
                for k, v in features.items()}
        rid = rep.engine.enqueue(item_keys, buckets, features,
                                 slo_s=slo_s, priority=priority,
                                 tenant=tenant,
                                 needs_kv_slot=needs_kv_slot)
        self.stats.n_enqueued += 1
        admitted = len(rep.engine.completed) == n_before
        if admitted:
            # Journal every admitted request until its response lands:
            # crash recovery replays unanswered entries onto the ring's
            # surviving owners (the no-drop invariant must not depend
            # on a single replica's memory).
            self._journal[rid] = _JournalEntry(
                item_keys=item_keys, buckets=buckets, features=features,
                arrival_s=arrival,
                slo_s=(self.cfg.overload_deadline_s if slo_s is None
                       else slo_s),
                priority=priority, tenant=tenant,
                needs_kv_slot=needs_kv_slot)
        # A rejection completes immediately; only ADMITTED traffic
        # earns hedge budget (rejected floods must not raise the cap).
        if self.hedge is not None and admitted:
            self.hedge.note_request()
        self._collect()                 # surface immediate rejections
        return rid

    # -- retrieval front end -------------------------------------------------
    def _attach_searcher(self) -> None:
        """Refresh the fleet searcher's shard list and point every live
        engine at it (a replica handles raw query strings by scatter-
        gathering across ALL live shards — its own stripe is just the
        part it stores and hands off)."""
        if self.searcher is None:
            return
        if hasattr(self.searcher, "set_fleet"):
            # FanoutSearcher: shard keys ARE replica ids (service
            # model, EWMAs, and mirrors key on them); membership
            # changes invalidate the stripe answer cache and drop
            # mirrors whose slow shard or host departed.
            self.searcher.set_fleet(
                [(r.replica_id, r.shard) for r in self.replicas
                 if r.shard is not None])
            live = self.searcher.mirrors
            for rep in self.replicas:
                rep.mirrors = {key: m for key, (host, m) in live.items()
                               if host == rep.replica_id}
        else:
            self.searcher.shards = [r.shard for r in self.replicas
                                    if r.shard is not None]
        for rep in self.replicas:
            rep.engine.retriever = self.searcher

    def partition_owners(self) -> Dict[int, str]:
        """Current doc-partition -> replica-id map (observability and
        the shard-ownership tests)."""
        return dict(self._part_owner)

    def set_shard_slowdown(self, replica_id: str, mult: float) -> None:
        """Chaos hook: pin (``mult > 1``) or clear (``mult <= 1``) a
        persistent service-time multiplier on one replica's shard —
        the degraded-disk scenario selective replication exists for.
        No-op without a fanout service model."""
        if hasattr(self.searcher, "set_slowdown"):
            self.searcher.set_slowdown(replica_id, mult)

    def _adapt_quorum(self) -> None:
        """Regime-ladder quorum adaptation, once per drain round: read
        the fleet's worst offered regime off the live schedulers and
        walk ``quorum_k`` one step — toward the full fan-out under
        Normal (converging to the bit-exact full gather), toward the
        configured floor under Very-Heavy (paying only the configured
        minimum of stragglers when every evaluation slot matters)."""
        q = getattr(self.searcher, "quorum", None)
        if q is None or not getattr(self.cfg, "fanout_adaptive_quorum",
                                    False):
            return
        regime = max((r.scheduler.offered_regime()
                      for r in self.replicas), default=0)
        n_shards = sum(1 for r in self.replicas if r.shard is not None)
        q.adapt(regime, n_shards)

    def _fanout_maintenance(self) -> None:
        """Selective stripe replication, run once per drain round: a
        replica whose probe EWMA marks it persistently slow gets its
        owned stripes mirrored onto its ring sibling (the existing
        ``export_docs -> absorb`` handoff path, deep-copied — the
        primary keeps serving), so shard-probe hedges have somewhere
        to land; mirrors drop once the EWMA recovers."""
        self._adapt_quorum()
        s = self.searcher
        if self.retrieval is None or not hasattr(s, "replication_due"):
            return
        for key in s.replication_due():
            rep = self.by_id.get(key)
            if rep is None or rep.shard is None or rep.shard.n_docs == 0:
                continue
            owned = sorted(p for p, r in self._part_owner.items()
                           if r == key)
            if not owned:
                continue
            sib = self.ring.sibling_for(
                self.retrieval.partition_key(owned[0]), exclude=(key,))
            if sib is None or sib not in self.by_id:
                continue
            mirror = mirror_shard_of(
                rep.shard,
                [self.retrieval.partition_doc_ids(p) for p in owned])
            self.by_id[sib].mirrors[key] = mirror
            s.add_mirror(key, sib, mirror)
            self.stats.n_stripe_replications += 1
        for key in s.mirrors_recovered():
            host = self.by_id.get(s.mirrors[key][0])
            if host is not None:
                host.mirrors.pop(key, None)
            s.drop_mirror(key)
            self.stats.n_mirror_drops += 1

    def enqueue_query(self, query: str, n_results: Optional[int] = None,
                      slo_s: Optional[float] = None,
                      priority: Priority = Priority.NORMAL,
                      tenant: str = "default",
                      needs_kv_slot: bool = False,
                      t_arrival: Optional[float] = None) -> int:
        """The full lifecycle front half, fleet edition: parse ->
        retrieve (scatter-gather across every live shard) -> route by
        tenant -> admit. Retrieval latency folds into the routed
        replica's LoadMonitor under the WarmupGate rule (wall clocks
        only), so its Ucapacity reflects the retrieve stage too."""
        if self.searcher is None:
            raise RuntimeError(
                "enqueue_query needs a retrieval front end (pass "
                "retrieval= to the coordinator)")
        k = (n_results if n_results is not None
             else getattr(self.cfg, "retrieve_top_k", 64))
        t0 = time.perf_counter()
        res = self.searcher.search(query, k)
        elapsed = time.perf_counter() - t0
        feats = dict(res.features)
        feats["trust"] = res.exact_trust
        self.route(tenant).engine.note_retrieval(
            len(res.url_ids), elapsed, feats)
        return self.enqueue(res.url_ids, res.buckets, feats,
                            slo_s=slo_s, priority=priority,
                            tenant=tenant, needs_kv_slot=needs_kv_slot,
                            t_arrival=t_arrival)

    def _partition_diff(self, *, remove: Optional[str] = None,
                        add=None) -> Dict[int, tuple]:
        """Doc-partitions a membership change would move:
        ``{partition: (old_owner, new_owner)}``. Must run BEFORE the
        ring mutates (and before fencing — a fenced replica no longer
        owns anything to diff)."""
        if self.retrieval is None:
            return {}
        diff = self.ring.remap_diff(self.retrieval.partition_keys(),
                                    remove=remove, add=add)
        return {self.retrieval.partition_index(key): owners
                for key, owners in diff.items()}

    def _move_partitions(self, moved: Dict[int, tuple],
                         joining=None, leaving=None,
                         rebuild: bool = False) -> None:
        """Commit a partition-ownership diff: each moved stripe leaves
        its old owner's shard and lands in the new owner's. On a
        graceful move the postings themselves travel
        (``export_docs``/``absorb`` — the index handoff next to the
        warm Trust-DB one); after a crash (``rebuild=True``) the dead
        shard is gone and the new owner re-indexes the stripe from the
        corpus."""
        if not moved or self.retrieval is None:
            return
        for p, (old_rid, new_rid) in sorted(moved.items()):
            docs = self.retrieval.partition_doc_ids(p)
            old = leaving if (leaving is not None
                              and leaving.replica_id == old_rid) \
                else self.by_id.get(old_rid)
            new = joining if (joining is not None
                              and joining.replica_id == new_rid) \
                else self.by_id.get(new_rid)
            if new is None or new.shard is None:   # pragma: no cover
                continue
            if rebuild or old is None or old.shard is None:
                sub = self.retrieval.build_partition(p)
                self.stats.n_partition_rebuilds += 1
            else:
                sub = old.shard.export_docs(docs)
                if len(sub.doc_len) != len(docs):  # pragma: no cover
                    sub = self.retrieval.build_partition(p)
            new.shard.absorb(sub)
            self._part_owner[p] = new.replica_id
            self.stats.n_partition_moves += 1
        self._attach_searcher()

    # -- elastic membership --------------------------------------------------
    def _next_replica_id(self) -> str:
        while True:
            rid = f"r{next(self._replica_seq)}"
            # Departed ids are not recycled either — their final stats
            # live on in the fleet aggregate under that name.
            if rid not in self.by_id and rid not in self._departed_sched:
                return rid

    def add_replica(self, handle: Optional[ReplicaHandle] = None, *,
                    weight: float = 1.0,
                    replica_id: Optional[str] = None,
                    now_t: Optional[float] = None,
                    prewarm: bool = False) -> ReplicaHandle:
        """Join a replica at runtime. With no ``handle`` a fresh one is
        built from the coordinator's own factory state (same config,
        evaluator, scheduler policy, and simulated rate as the seed
        fleet — and the SHARED request-id source, so fleet-unique ids
        survive the join). A caller-built handle must share that id
        source itself.

        The ring rebalances minimally (only the tenants the new replica
        claims move), and on simulated fleets the newcomer's clock
        fast-forwards to ``now_t`` (default: the latest arrival
        timestamp the fleet has seen) — a replica joining now cannot
        complete work in the past, but it also does not inherit a busy
        sibling's backlog-inflated clock.

        ``prewarm=True`` (the feedforward-join path) primes the
        newcomer's evaluator at the live fleet's production shapes
        BEFORE the ring can route a tenant to it, so its first real
        batch runs jit-warm. Skipped silently when no traffic has been
        seen yet (there is no schema to warm against — and nothing to
        be slow for either)."""
        if handle is None:
            rid = replica_id or self._next_replica_id()
            handle = ReplicaHandle(
                rid, self.cfg, self._evaluate_chunk, weight=weight,
                sched_cfg=self._base_sched,
                sim_rate_items_per_s=self._sim_rate,
                request_ids=self._ids,
                drain_mode=self._drain_mode,
                evaluate_batch=self._evaluate_batch,
                feature_sharding=self._feature_sharding)
        if handle.replica_id in self.by_id:
            raise ValueError(
                f"replica {handle.replica_id!r} already in the fleet")
        if handle.replica_id in self._departed_sched:
            raise ValueError(
                f"replica id {handle.replica_id!r} belonged to a "
                f"departed replica whose stats live on under that name")
        # Plan the stripe moves BEFORE the ring mutates: "which
        # partitions does the newcomer claim" is a diff against the
        # pre-join membership.
        moved = self._partition_diff(
            add=(handle.replica_id, handle.weight))
        handle.advance_to(self._now_hint if now_t is None else now_t)
        self._attach_capacity(handle)
        if prewarm and self._feature_schema is not None:
            # Warm BEFORE ring.add: once the id is on the ring a tenant
            # can route here, and the whole point is that no real
            # request ever meets a cold jit cache.
            handle.prewarm(self._feature_schema, self.max_batch_items)
            self.stats.n_prewarm_joins += 1
            self._prewarm_watch[handle.replica_id] = \
                handle.warmup_exclusions()
        self.ring.add(handle.replica_id, handle.weight)
        self.replicas.append(handle)
        self.by_id[handle.replica_id] = handle
        self.stats.n_joins += 1
        if self.retrieval is not None:
            # Build/load the newcomer's shard: exactly the stripes the
            # ring hands it, loaded from their old owners' postings.
            handle.shard = self.retrieval.build_shard([])
            self._move_partitions(moved, joining=handle)
        cc = self.cluster_cfg
        if self.hedge is None and cc.hedge_after_s > 0 \
                and self.n_replicas > 1:
            # A backup exists now: cluster hedging switches on.
            self.hedge = HedgedDispatch(cc.hedge_after_s,
                                        max_hedges=cc.max_hedges,
                                        budget_frac=cc.hedge_budget_frac)
        return handle

    def remove_replica(self, replica_id: str, drain: bool = True) -> int:
        """Leave (``drain=True``) or crash (``drain=False``) a replica
        at runtime; returns the number of queued requests migrated.

        Graceful leave: the replica is fenced from routing, then its
        backlog hands off to the ring's new owners in drain order
        (strict priority, EDF within class). A handed-off copy whose
        hedge twin is already queued on a surviving replica is dropped
        — deduplicated at the handoff instead of racing twice.

        Crash: the engine state is lost wholesale; the admission
        journal replays every unanswered request with no live copy on a
        surviving replica onto the ring's new owner. Responses the dead
        replica already produced were already delivered (collected
        first), so they count — but its queues, Trust-DB, and prior are
        gone."""
        if replica_id not in self.by_id:
            raise KeyError(replica_id)
        if self.n_replicas == 1:
            raise ValueError("cannot remove the last replica")
        rep = self.by_id[replica_id]
        # In-flight pipelined batches land first: a graceful leave waits
        # for its window (those responses are about to be collected); a
        # crash loses a real machine's in-flight work too, but THIS
        # in-process stand-in has already mutated the shared Trust-DB
        # arrays, so finalizing keeps the accounting consistent.
        rep.engine.flush()
        # Responses the replica already produced left the building
        # before the leave/crash — collect them while the cursor lives.
        # Its un-harvested cache-fill deltas likewise: they happened,
        # so they count (and gossip) before the member disappears.
        self._collect()
        self._harvest_cache_deltas()
        # Warm-state handoff plan must be computed BEFORE fencing: the
        # new owners are "who the ring gives this replica's tenants
        # to", and a fenced replica no longer owns anything to diff.
        new_owner_ids: set = set()
        if drain and self.cluster_cfg.warm_handoff_top_k > 0:
            diff = self.ring.remap_diff(sorted(self.tenants_seen),
                                        remove=replica_id)
            new_owner_ids = {new for old, new in diff.values()
                             if old == replica_id}
        # Same pre-fence rule for the index stripes: the handoff plan
        # is "who inherits this replica's partitions", and a fenced
        # replica owns none.
        part_moved = self._partition_diff(remove=replica_id)
        self.ring.fence(replica_id)     # no fresh routes from here on
        migrated = 0
        if drain:
            migrated = self._handoff_queue(rep)
            self._handoff_warm_cache(rep, new_owner_ids)
            # Index handoff rides next to the warm Trust-DB one: the
            # leaving shard's postings travel to the stripes' new
            # owners instead of being re-indexed.
            self._move_partitions(part_moved, leaving=rep)
            self.stats.n_leaves += 1
        # Drop the member BEFORE journal replay so recovery routes and
        # twin-scans only see survivors.
        self._departed_sched[replica_id] = rep.scheduler.stats.as_dict()
        self.ring.remove(replica_id)
        self.replicas.remove(rep)
        del self.by_id[replica_id]
        if not drain:
            migrated = self._crash_recover()
            # The dead shard is gone wholesale: survivors re-index the
            # crashed stripes from the corpus (the corpus is durable
            # shared storage; only the built postings were lost).
            self._move_partitions(part_moved, rebuild=True)
            self.stats.n_crashes += 1
        if self.autoscaler is not None:
            self.autoscaler.forget(replica_id)
        self._attach_searcher()         # drop the departed shard
        return migrated

    def _queued_rids(self, exclude: Optional[ReplicaHandle] = None
                     ) -> set:
        """Request ids with a live queued copy anywhere in the fleet
        (optionally excluding one replica) — the hedge-twin scan."""
        return {q.request.request_id
                for rep in self.replicas if rep is not exclude
                for p in Priority
                for q in rep.bank.queues[p].entries()}

    def _handoff_queue(self, leaving: ReplicaHandle) -> int:
        """Drain-and-handoff: pop the leaving replica's queue in drain
        order and push each request to the ring's new owner for its
        tenant. EDF keys (absolute deadlines) travel with the requests,
        so every surviving queue stays EDF-ordered and no surviving
        head is displaced by anything later-deadlined."""
        twins = self._queued_rids(exclude=leaving)
        migrated = 0
        for qreq in leaving.export_queue():
            rid = qreq.request.request_id
            if rid in twins:
                # A hedge twin of this request is already queued on a
                # surviving replica — the race is decided by the leave:
                # keep the survivor, drop this copy.
                self.stats.n_handoff_twin_drops += 1
                self.stats.n_twin_drops += 1
                continue
            owner = self.by_id[self.ring.route(qreq.tenant)]
            # Same timeline rule as stealing: the request has been
            # queued since enqueue_t — the new owner's clock only lags
            # because nothing happened on it.
            owner.advance_to(qreq.enqueue_t)
            if owner.import_queued(qreq):
                migrated += 1
                self.stats.n_handoffs += 1
            else:                       # receiver full: explicit reject
                self._reject_overflow(owner, qreq)
        return migrated

    def _handoff_warm_cache(self, leaving: ReplicaHandle,
                            new_owner_ids: set) -> None:
        """Warm Trust-DB handoff (graceful leave): ship the leaving
        replica's top-K freshest ``(url, trust)`` cache entries to the
        ring's new owners through the existing ``apply_trust_deltas``
        path — the tenants' hot URLs keep answering from cache instead
        of re-warming one duplicate evaluation at a time through
        gossip. Inserts only, prior stays local (same poisoning
        isolation as gossip)."""
        if not new_owner_ids:
            return
        keys, vals = leaving.export_cache(
            self.cluster_cfg.warm_handoff_top_k)
        if len(keys) == 0:
            return
        delivered = False
        for rid in sorted(new_owner_ids):
            owner = self.by_id.get(rid)
            if owner is not None and owner is not leaving:
                owner.apply_trust_deltas(keys, vals)
                delivered = True
        if delivered:
            # Distinct (url, trust) pairs that left the replica — NOT
            # multiplied by the receiving fan-out.
            self.stats.n_warm_handoff_entries += len(keys)

    def _reject_overflow(self, owner: ReplicaHandle,
                         qreq: QueuedRequest) -> None:
        """Backpressure during a handoff: the receiving queue is full,
        so the request completes as an explicit prior-answered
        rejection (never a silent drop) on the receiving replica."""
        sched = owner.scheduler
        resp = sched._reject(qreq.request, qreq.priority,
                             sched.offered_regime(qreq.n_items),
                             REASON_QUEUE_FULL)
        sched.stats.n_rejected += 1
        sched.stats.rejected_by_reason[REASON_QUEUE_FULL] = \
            sched.stats.rejected_by_reason.get(REASON_QUEUE_FULL, 0) + 1
        owner.engine.completed.append(resp)

    def _crash_recover(self) -> int:
        """Journal replay after a crash: re-dispatch every admitted,
        unanswered request that has no live copy on a surviving replica
        (a queued hedge twin counts as the live copy) to the ring's new
        owner for its tenant. Re-entry happens at the fleet's current
        time — the latest arrival timestamp, not a busy sibling's
        backlog-inflated clock — with the ORIGINAL arrival and
        deadline, so recovered requests keep their EDF position and
        their latency accounting stays honest."""
        still_queued = self._queued_rids()
        now_t = self._now_hint
        recovered = 0
        for rid, e in sorted(self._journal.items()):
            if rid in self._responded or rid in still_queued:
                continue
            req = Request(rid, e.item_keys, e.buckets, e.features,
                          arrival_s=e.arrival_s, slo_s=e.slo_s,
                          needs_kv_slot=e.needs_kv_slot)
            qreq = QueuedRequest(request=req, priority=e.priority,
                                 tenant=e.tenant,
                                 deadline_t=e.arrival_s + e.slo_s,
                                 enqueue_t=now_t)
            owner = self.by_id[self.ring.route(e.tenant)]
            owner.advance_to(now_t)
            if owner.import_queued(qreq):
                recovered += 1
                self.stats.n_crash_recovered += 1
            else:
                self._reject_overflow(owner, qreq)
        return recovered

    def _autoscale_membership(
            self, heap: Optional[ReplicaLoadHeap] = None,
            forecast_pressure: Optional[float] = None) -> None:
        """Let the autoscaler's fleet-pressure vote change membership
        (bounded by [min_replicas, max_replicas], hysteresis inside the
        policy). Scale-down drains the lightest-loaded replica out —
        picked from the round's load heap in O(1) when one is live.
        Held steady while a rolling restart executes (fencing waves
        must not race membership changes).

        ``forecast_pressure`` (the planner's predicted utilization) is
        folded into the SAME vote, so a feedforward join shares the
        reactive cooldown window instead of bypassing it. A join voted
        while the planner is active is pre-warmed before it can serve
        and logged with the forecast that triggered it."""
        cc = self.cluster_cfg
        if self.autoscaler is None or cc.max_replicas <= 0 \
                or self._restart_hold:
            return
        vote = self.autoscaler.membership_decision(
            self.n_replicas, cc.min_replicas, cc.max_replicas,
            forecast_pressure=forecast_pressure)
        if vote > 0:
            rep = self.add_replica(prewarm=self.planner is not None)
            if self.planner is not None:
                self.planner_log.append({
                    "t": self._now_hint,
                    "event": "prewarm_join",
                    "replica": rep.replica_id,
                    "forecast_pressure": float(forecast_pressure or 0.0),
                    "pressure": float(self.autoscaler.pressure),
                    "n_replicas": self.n_replicas})
        elif vote < 0:
            victim_id = None
            if heap is not None and len(heap) == self.n_replicas:
                cold = heap.coldest()
                if cold is not None and cold[0] in self.by_id:
                    victim_id = cold[0]
            if victim_id is None:
                victim_id = min(
                    self.replicas,
                    key=lambda r: (r.queued_items, r.replica_id)
                ).replica_id
            self.remove_replica(victim_id, drain=True)

    # -- coordinated rolling restarts -----------------------------------------
    def plan_restart_waves(self, max_wave_frac: float = 0.25
                           ) -> List[List[str]]:
        """Pack the fleet into ring-disjoint restart waves.

        No replica shares a wave with one of its ring *inheritors*
        (the replicas its tenants and doc-partitions would route to
        while it is fenced): fencing a replica together with its
        successor would bounce the handed-off backlog twice and leave
        a tenant's whole route chain dark. Waves are additionally
        capped at ``max_wave_frac`` of the fleet (at least 1, at most
        n-1 — someone must stay up to serve)."""
        rids = sorted(self.by_id)
        n = len(rids)
        if n <= 1:
            raise ValueError(
                "rolling restart needs at least 2 replicas")
        cap = min(max(1, int(n * max_wave_frac)), n - 1)
        tenants = sorted(self.tenants_seen)
        succ: Dict[str, set] = {}
        for rid in rids:
            inheritors: set = set()
            if tenants:
                diff = self.ring.remap_diff(tenants, remove=rid)
                inheritors |= {new for old, new in diff.values()
                               if old == rid}
            if self.retrieval is not None:
                pdiff = self.ring.remap_diff(
                    self.retrieval.partition_keys(), remove=rid)
                inheritors |= {new for old, new in pdiff.values()
                               if old == rid}
            if not inheritors:
                # Owns no known tenant/partition: still keep its ring
                # sibling out of the wave (whoever WOULD inherit).
                sib = self.ring.sibling_for(rid, exclude=(rid,))
                if sib is not None:
                    inheritors.add(sib)
            succ[rid] = inheritors
        waves: List[List[str]] = []
        for rid in rids:
            placed = False
            for wave in waves:
                if len(wave) >= cap:
                    continue
                if all(rid not in succ[w] and w not in succ[rid]
                       for w in wave):
                    wave.append(rid)
                    placed = True
                    break
            if not placed:
                waves.append([rid])
        return waves

    def rolling_restart(self, downtime_s: float = 0.0,
                        max_wave_frac: float = 0.25
                        ) -> List[List[str]]:
        """Restart every replica in ring-disjoint waves without losing
        a request or a membership slot.

        Per wave: fence all members -> flush + collect their in-flight
        work -> hand the queued backlog off to the (unfenced) ring
        owners -> rebuild each member's engine in place (fresh
        scheduler/shedder/cache/prior — the index shard survives, it
        lives on durable storage; the warm cache does not, which is
        what a real process restart costs) -> unfence. The autoscaler
        holds membership steady for the whole plan
        (``_autoscale_membership`` is suppressed), and each member's
        pre-restart scheduler counters fold into the fleet-lifetime
        aggregate so ``scheduler_stats`` never dips. Returns the
        executed waves."""
        waves = self.plan_restart_waves(max_wave_frac)
        self._restart_hold = True
        try:
            for wave in waves:
                members = [self.by_id[r] for r in wave
                           if r in self.by_id]
                for rep in members:
                    self.ring.fence(rep.replica_id)
                for rep in members:
                    rep.engine.flush()
                self._collect()
                self._harvest_cache_deltas()
                for rep in members:
                    # Fenced => the ring routes every handed-off
                    # request to a surviving (unfenced) replica; hedge
                    # twins dedup exactly as on a graceful leave.
                    self._handoff_queue(rep)
                for rep in members:
                    self._bank_restart_stats(rep)
                    rep.restart(now_t=self._now_hint,
                                downtime_s=downtime_s)
                    self._attach_capacity(rep)
                    if self.autoscaler is not None:
                        self.autoscaler.forget(rep.replica_id)
                    self.stats.n_restarts += 1
                for rep in members:
                    self.ring.unfence(rep.replica_id)
                self._attach_searcher()
                self.stats.n_restart_waves += 1
        finally:
            self._restart_hold = False
        return waves

    _SCHED_INT_KEYS = ("n_submitted", "n_admitted", "n_rejected",
                       "n_batches", "n_batched_items", "n_hedges",
                       "n_executor_errors", "n_quarantined")

    @classmethod
    def _merge_sched_stats(cls, dst: Dict, src: Dict) -> None:
        for k in cls._SCHED_INT_KEYS:
            dst[k] = dst.get(k, 0) + src.get(k, 0)
        rbr = dst.setdefault("rejected_by_reason", {})
        for reason, c in src.get("rejected_by_reason", {}).items():
            rbr[reason] = rbr.get(reason, 0) + c

    def _bank_restart_stats(self, rep: ReplicaHandle) -> None:
        """Fold a replica's pre-restart scheduler counters into its
        lifetime base (the rebuilt engine starts from zero, the fleet
        aggregate must not)."""
        base = self._restart_sched_base.setdefault(
            rep.replica_id, {"rejected_by_reason": {}})
        self._merge_sched_stats(base, rep.scheduler.stats.as_dict())

    # -- Trust-DB gossip -----------------------------------------------------
    def _harvest_cache_deltas(self) -> None:
        """Collect every replica's fresh-evaluation taps: account
        fleet-wide duplicate evaluations, and (with gossip on) publish
        the deltas for this round's bounded broadcast."""
        for rep in self.replicas:
            for keys, vals in rep.take_cache_deltas():
                self.stats.n_eval_items += len(keys)
                for k in keys.tolist():
                    c = self._eval_counts.get(k, 0)
                    if c:
                        self.stats.n_duplicate_evals += 1
                    self._eval_counts[k] = c + 1
                if self.gossip is not None:
                    self.gossip.publish(rep.replica_id, keys, vals)

    # -- steal ---------------------------------------------------------------
    def _steal_rebalance(self,
                         heap: Optional[ReplicaLoadHeap] = None) -> None:
        """Migrate work from the hottest bank to the idlest while the
        imbalance exceeds the threshold. Steals come off the BACK of the
        victim's lowest-importance non-empty class and a class is never
        robbed below 2 entries, so every EDF head stays put. With
        ``cost_aware_steal`` the non-head candidate with the highest
        estimated eval cost on the victim (items x Trust-DB miss
        probability) leaves — a stolen chunk of cache-hot requests
        would displace cache-cold work only to re-evaluate warm items
        on the thief's cold cache.

        Hot/cold picks read the round's :class:`ReplicaLoadHeap` (each
        steal touches exactly two replicas, updated in O(log n))
        instead of re-sorting the fleet per iteration — the former
        O(steals x n log n) per-round scan cost, which is what capped
        the rebalancer at 32-64 replicas. Tie-breaks match the old
        ``sorted`` order exactly, so only the complexity changed."""
        if self.n_replicas < 2:
            return
        if heap is None:
            heap = ReplicaLoadHeap({r.replica_id: r.queued_items
                                    for r in self.replicas})
        # Per-scan cost memo: a candidate scored but left behind this
        # round keeps its score on the next steal_back call (a victim's
        # cache only changes when a batch lands, not mid-scan) —
        # scoring is a device lookup, so pay it once per (victim,
        # thief, entry). Keyed by victim too: the same request
        # re-scored on a different replica after a move sees THAT
        # replica's cache — and by thief, because decode KV-slot
        # pressure is a property of where the work would LAND.
        memo: Dict[tuple, float] = {}

        def _costed(rep, thief):
            def fn(qreq):
                key = (rep.replica_id, thief.replica_id, id(qreq))
                if key not in memo:
                    memo[key] = rep.steal_cost(qreq, thief=thief)
                return memo[key]
            return fn

        for _ in range(self.cluster_cfg.max_steals_per_round):
            cold, hot_top = heap.coldest(), heap.hottest()
            if cold is None or hot_top is None:
                break
            idle, hot = self.by_id[cold[0]], self.by_id[hot_top[0]]
            gap = hot_top[1] - cold[1]
            if gap < self.cluster_cfg.steal_threshold_items:
                break
            qreq = hot.bank.steal_back(
                cost_fn=(_costed(hot, idle)
                         if self.cluster_cfg.cost_aware_steal
                         else None))
            if qreq is None:            # nothing stealable (heads only)
                break
            if getattr(qreq.request, "needs_kv_slot", False):
                free = idle.kv_free_slots()
                if free is not None and free <= 0:
                    # Decode work cannot progress on a thief with no
                    # claimable KV slots — the cost fold already steers
                    # the picker away, but when every stealable entry
                    # is decode (the picker had nothing else), veto the
                    # migration outright: undo and stop this round.
                    hot.bank.push(qreq)
                    break
            if qreq.n_items >= gap:
                # Moving it would leave the gap as large or larger
                # (just inverted) — the same jumbo request would be
                # stolen straight back next iteration. Undo and stop.
                hot.bank.push(qreq)
                break
            # The request has been queued (hence stealable) since its
            # enqueue time — the victim's clock being further ahead only
            # means the victim already worked deep into ITS backlog.
            idle.advance_to(qreq.enqueue_t)
            if not idle.bank.push(qreq):
                hot.bank.push(qreq)     # thief full: undo, stop trying
                break
            self.stats.n_steals += 1
            heap.update(hot.replica_id, hot.queued_items)
            heap.update(idle.replica_id, idle.queued_items)

    # -- hedge ---------------------------------------------------------------
    def _backup_for(self, tenant: str, current: ReplicaHandle,
                    n_prior_hedges: int = 0
                    ) -> Optional[ReplicaHandle]:
        """Hedge target for the ``n_prior_hedges + 1``-th dispatch of a
        ``tenant`` request waiting on ``current``.

        The k-th hedge walks to the k-th distinct ring replica past the
        primary, so a RE-hedge (the backup is itself overloaded)
        escalates to a replica that does not already hold a copy
        instead of bouncing between the primary/backup pair. Skips
        ``current`` (a stolen copy may sit off its chain position);
        None once the chain is exhausted — every replica has a copy."""
        chain = self.ring.route_chain(tenant, self.n_replicas)
        for rid in chain[n_prior_hedges + 1:]:
            if rid != current.replica_id:
                return self.by_id[rid]
        return None

    def _hedge_scan(self) -> None:
        """Re-dispatch requests stuck past the hedge latency onto a real
        backup replica at CRITICAL priority. Twins race; ``_collect``
        keeps the first completion and drops the loser."""
        if self.hedge is None or self.hedge.budget_available < 1.0:
            return          # tokens only refill on enqueue, not mid-scan
        for rep in self.replicas:
            if rep.queued_items == 0:
                continue    # nothing waiting: skip the class walk
            now = rep.now()
            for p in Priority:
                for qreq in rep.bank.queues[p].entries():
                    if not self.hedge.should_hedge(
                            now - qreq.hedge_wait_base_t,
                            qreq.n_hedges):
                        continue
                    backup = self._backup_for(qreq.tenant, rep,
                                              qreq.n_hedges)
                    if backup is None:      # every replica has a copy
                        continue
                    # In continuous time the hedge fires the moment the
                    # wait (since the last dispatch) crosses the hedge
                    # latency.
                    fire_t = qreq.hedge_wait_base_t \
                        + self.hedge.hedge_after_s
                    backup.advance_to(fire_t)
                    if qreq.dispatch_twin(
                            backup.bank.queues[Priority.CRITICAL].push,
                            fire_t):
                        self.hedge.record_hedge()
                        self.stats.n_hedges += 1

    # -- drain ---------------------------------------------------------------
    def drain(self, max_rounds: Optional[int] = None) -> List[Response]:
        """Round-robin drain: poll + steal + hedge scans, then one
        micro-batch per replica, until every bank is empty and every
        pipeline window has landed (or ``max_rounds``). Returns the NEW
        responses produced (deduplicated).

        Fused replicas with ``pipeline_depth >= 2`` dispatch their
        batch and return WITHOUT syncing (``flush=False``): the device
        steps of round N overlap round N+1's steal/hedge scans and
        batch formation, instead of the fleet paying one full device
        round-trip per replica per round. The ``poll`` at the top of
        each round folds every batch that has since landed back into
        its replica's LoadMonitor / Trust-DB tap / response log FIRST,
        so the steal, hedge, autoscale, and gossip decisions that
        follow read stats as fresh as the hardware can make them —
        not one batch late (the former ROADMAP gap)."""
        produced: List[Response] = []
        rounds = 0
        while max_rounds is None or rounds < max_rounds:
            # Fold completed in-flight batches back BEFORE deciding
            # anything: steal/hedge/autoscale read fresh stats.
            for rep in self.replicas:
                rep.engine.poll()
            # ONE load index per round (O(n) heapify over the polled
            # queue depths): the steal loop updates it per steal and
            # the autoscale victim pick reads it, instead of each scan
            # re-sorting the fleet.
            heap = ReplicaLoadHeap({r.replica_id: r.queued_items
                                    for r in self.replicas})
            self._steal_rebalance(heap)
            self._hedge_scan()
            self._fanout_maintenance()
            any_batch = False
            for rep in list(self.replicas):
                # n_submitted counts rescued batches too: a batch whose
                # dispatch raised still consumed queue work (and was
                # prior-answered), so the round made progress.
                before = rep.scheduler.executor.n_submitted
                rep.engine.drain(max_batches=1, flush=False)
                any_batch |= \
                    rep.scheduler.executor.n_submitted > before
                if rep.replica_id in heap:
                    heap.update(rep.replica_id, rep.queued_items)
                if rep.replica_id in self._prewarm_watch \
                        and rep.scheduler.stats.n_batches > 0:
                    # First real batch after a pre-warmed join: any NEW
                    # warmup exclusion means a jit shape the prewarm
                    # missed — the join was cold after all.
                    if rep.warmup_exclusions() > \
                            self._prewarm_watch.pop(rep.replica_id):
                        self.stats.n_cold_joins += 1
            # Gossip: harvest this round's cache fills (duplicate-eval
            # accounting either way), then broadcast the freshest
            # deltas to siblings under the per-round budget.
            self._harvest_cache_deltas()
            if self.gossip is not None:
                self.gossip.flush(self.replicas)
            produced.extend(self._collect())
            rounds += 1
            self.stats.n_drain_rounds += 1
            if self.autoscaler is not None and \
                    self.stats.n_drain_rounds \
                    % max(self.cluster_cfg.autoscale_every, 1) == 0:
                self.last_snapshot = self.autoscaler.update(
                    self.replicas, self.tenants_seen)
                fp = None
                if self.planner is not None:
                    fp = self.planner.forecast_pressure(
                        self._now_hint,
                        rate_items_per_s=(
                            self.last_snapshot.rate_items_per_s))
                self._autoscale_membership(heap, forecast_pressure=fp)
            if not any_batch:
                # Queues are empty; land whatever is still in flight
                # (their fold-backs may gossip) and finish.
                for rep in self.replicas:
                    rep.engine.flush()
                self._harvest_cache_deltas()
                if self.gossip is not None:
                    self.gossip.flush(self.replicas)
                produced.extend(self._collect())
                break
        return produced

    def _collect(self) -> List[Response]:
        """Pull new responses off every replica, keeping the FIRST
        completion per request id (hedge losers are dropped here — the
        fleet-wide dedup).

        When both twins complete within the same collection window,
        "first" is decided by completion time — twins share an arrival,
        so lower latency IS earlier completion — not by replica scan
        order (the hedge exists precisely because the primary is slow,
        and scan order would keep the loser)."""
        window: List[Response] = []
        for rep in self.replicas:
            comp = rep.engine.completed
            while rep.n_collected < len(comp):
                window.append(comp[rep.n_collected])
                rep.n_collected += 1
        by_rid: Dict[int, Response] = {}
        order: List[int] = []
        for resp in window:
            rid = resp.request_id
            if rid in self._responded:      # twin answered last window
                self.stats.n_twin_drops += 1
                continue
            if rid in by_rid:               # both twins in this window
                self.stats.n_twin_drops += 1
                if resp.latency_s < by_rid[rid].latency_s:
                    by_rid[rid] = resp
                continue
            by_rid[rid] = resp
            order.append(rid)
        fresh = [by_rid[rid] for rid in order]
        for resp in fresh:
            self._responded.add(resp.request_id)
            self.completed.append(resp)
            self._journal.pop(resp.request_id, None)    # answered
            if resp.admitted:
                self.capacity.observe_queue(resp.queue_delay_s)
        return fresh

    # -- observability -------------------------------------------------------
    def slo_stats(self) -> Dict[str, float]:
        return slo_stats_of(self.completed)

    def scheduler_stats(self) -> Dict:
        """Fleet aggregate in the single-engine stats shape (drivers and
        reports consume both interchangeably), plus cluster extras."""
        agg: Dict = {k: 0 for k in self._SCHED_INT_KEYS}
        agg["rejected_by_reason"] = {}
        per_replica: Dict[str, Dict] = {}
        live = {rep.replica_id: rep.scheduler.stats.as_dict()
                for rep in self.replicas}
        # Departed replicas' final counters stay in the fleet aggregate
        # (membership churn must not erase submission history), and a
        # restarted replica's pre-restart base folds back under its
        # still-live id (the rebuilt engine counts from zero).
        for rid, s in list(self._departed_sched.items()) \
                + list(live.items()):
            entry: Dict = {"rejected_by_reason": {}}
            self._merge_sched_stats(entry, s)
            base = self._restart_sched_base.get(rid)
            if base is not None:
                self._merge_sched_stats(entry, base)
            entry["mean_batch_fill"] = (entry["n_batched_items"]
                                        / max(entry["n_batches"], 1))
            per_replica[rid] = entry
            self._merge_sched_stats(agg, entry)
        agg["n_hedges"] += self.stats.n_hedges
        agg["mean_batch_fill"] = (agg["n_batched_items"]
                                  / max(agg["n_batches"], 1))
        agg["cluster"] = self.stats.as_dict()
        agg["per_replica"] = per_replica
        if self.last_snapshot is not None:
            agg["autoscale"] = self.last_snapshot.as_dict()
        if self.gossip is not None:
            agg["gossip"] = self.gossip.stats.as_dict()
        if hasattr(self.searcher, "gather_stats"):
            agg["fanout"] = self.searcher.gather_stats()
        agg["capacity"] = self.capacity.fitted()
        if self.planner is not None:
            agg["forecast"] = {
                **self.planner.stats(),
                "n_prewarm_joins": self.stats.n_prewarm_joins,
                "n_cold_joins": self.stats.n_cold_joins,
                "log": list(self.planner_log)}
        return agg
