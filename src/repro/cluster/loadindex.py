"""Heap-indexed hot/cold replica tracking for fleet scans.

The coordinator's steal loop used to re-sort EVERY replica by queue
depth on EVERY steal iteration — O(steals x n log n) per drain round,
which at the ROADMAP's 32-64 replica fleet sizes makes the rebalance
scan itself a per-round cost comparable to a micro-batch. Each steal
only changes TWO replicas' loads (the donor and the receiver), so the
ordering is a textbook priority-queue workload:

* build once per round from the polled queue depths (O(n) heapify),
* read the coldest/hottest replica in O(1),
* update the two touched replicas in O(log n) per steal,
* keep serving autoscale victim picks and hedge-scan skips from the
  same index for the rest of the round.

Implemented as a lazy-deletion double heap (one min-heap, one
max-heap over the same load map): ``update`` pushes a fresh entry and
leaves the stale one in place; reads pop until the top entry matches
the live load map. Every entry is pushed at most once per update, so
the amortized cost stays O(log n) and no rebalancing pass exists.

Tie-breaking matches the ``sorted(..., key=(queued_items,
replica_id))`` order the scans used before — coldest = smallest
(load, id), hottest = largest (load, id) — so replacing the sorts
changes complexity, never behaviour.
"""
from __future__ import annotations

import heapq
from typing import Dict, Iterable, List, Optional, Tuple


class _RevStr:
    """String wrapper with inverted ordering, so the max-heap breaks
    load ties toward the LARGEST replica id — exactly the replica the
    old ``sorted(...)[-1]`` scan picked."""

    __slots__ = ("s",)

    def __init__(self, s: str) -> None:
        self.s = s

    def __lt__(self, other: "_RevStr") -> bool:
        return other.s < self.s

    def __eq__(self, other: object) -> bool:
        return isinstance(other, _RevStr) and self.s == other.s


class ReplicaLoadHeap:
    """Lazy-deletion min/max heap over ``{replica_id: load}``."""

    def __init__(self, loads: Optional[Dict[str, int]] = None) -> None:
        self._load: Dict[str, int] = dict(loads or {})
        self._minh: List[Tuple[int, str]] = [
            (ld, rid) for rid, ld in self._load.items()]
        self._maxh: List[Tuple[int, _RevStr]] = [
            (-ld, _RevStr(rid)) for rid, ld in self._load.items()]
        heapq.heapify(self._minh)
        heapq.heapify(self._maxh)

    def __len__(self) -> int:
        return len(self._load)

    def __contains__(self, rid: str) -> bool:
        return rid in self._load

    def load_of(self, rid: str) -> int:
        return self._load[rid]

    def update(self, rid: str, load: int) -> None:
        """Set ``rid``'s load (also inserts unseen ids): O(log n)."""
        load = int(load)
        if self._load.get(rid) == load:
            return
        self._load[rid] = load
        heapq.heappush(self._minh, (load, rid))
        heapq.heappush(self._maxh, (-load, _RevStr(rid)))

    def remove(self, rid: str) -> None:
        """Forget a departed replica (stale heap entries decay lazily)."""
        self._load.pop(rid, None)

    def coldest(self) -> Optional[Tuple[str, int]]:
        """(replica_id, load) with the smallest (load, id), or None."""
        while self._minh:
            ld, rid = self._minh[0]
            if self._load.get(rid) == ld:
                return rid, ld
            heapq.heappop(self._minh)       # stale: superseded/removed
        return None

    def hottest(self) -> Optional[Tuple[str, int]]:
        """(replica_id, load) with the largest (load, id), or None."""
        while self._maxh:
            negld, rev = self._maxh[0]
            if self._load.get(rev.s) == -negld:
                return rev.s, -negld
            heapq.heappop(self._maxh)
        return None

    def gap(self) -> int:
        """hottest load - coldest load (0 when fewer than 2 replicas)."""
        hot, cold = self.hottest(), self.coldest()
        if hot is None or cold is None:
            return 0
        return hot[1] - cold[1]
