"""Consistent-hash tenant routing: tenants -> replica shards.

Weighted-virtual-node consistent hashing (the Dynamo/Cassandra ring,
the standard answer vertical-search capacity planning gives for
balancing per-replica load): every replica owns
``round(weight * vnodes_per_weight)`` points on a 64-bit ring, a tenant
routes to the first replica point clockwise from the tenant's own hash.

Properties the cluster relies on (property-tested in
``tests/test_cluster.py``):

* **deterministic** — hashing is ``md5`` over stable strings, so the
  same membership maps the same tenants to the same replicas in every
  process, with no coordination;
* **minimal rebalancing** — removing a replica deletes only its own
  points: tenants previously routed to *other* replicas keep their
  mapping (only the removed replica's tenants remap, to the next point
  clockwise). Joins are symmetric;
* **weighted** — a replica with twice the weight owns ~twice the ring
  arc, hence ~twice the tenants in expectation.

``route_chain`` returns the first ``k`` *distinct* replicas clockwise —
the primary plus the backups hedged dispatch races against.

Elastic membership (runtime join/leave) adds two facilities:

* **fencing** — ``fence(replica_id)`` excludes a replica from every
  route/chain WITHOUT deleting its points: new traffic flows to the
  next point clockwise (exactly where a removal would send it) while
  the fenced replica drains, and ``unfence`` restores the original
  mapping bit for bit. A leaving replica is fenced first so the
  drain-and-handoff never races fresh arrivals.
* **remap diff** — ``remap_diff(tenants, remove=..., add=...)`` plans a
  membership change: the exact ``{tenant: (old_owner, new_owner)}`` set
  a join/leave would disturb, computed without mutating live state
  (points are deterministic, so apply-then-restore is exact).
"""
from __future__ import annotations

import bisect
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple


def stable_hash(s: str) -> int:
    """64-bit position on the ring; md5 so it is stable across
    processes and Python hash randomization."""
    return int.from_bytes(hashlib.md5(s.encode()).digest()[:8], "big")


class ConsistentHashRing:
    def __init__(self, vnodes_per_weight: int = 64):
        if vnodes_per_weight <= 0:
            raise ValueError("vnodes_per_weight must be positive")
        self.vnodes_per_weight = int(vnodes_per_weight)
        self.weights: Dict[str, float] = {}
        self.fenced: set = set()                    # ids excluded from routing
        self._points: List[Tuple[int, str]] = []    # sorted (hash, id)
        self._keys: List[int] = []                  # parallel hash keys

    def __len__(self) -> int:
        return len(self.weights)

    def __contains__(self, replica_id: str) -> bool:
        return replica_id in self.weights

    @property
    def replica_ids(self) -> List[str]:
        return sorted(self.weights)

    def _vnode_count(self, weight: float) -> int:
        return max(1, round(weight * self.vnodes_per_weight))

    def _rebuild_keys(self) -> None:
        self._keys = [h for h, _ in self._points]

    def add(self, replica_id: str, weight: float = 1.0) -> None:
        """Join: inserts only this replica's points (deterministic —
        every point is ``md5(id#vnode)`` — so rebalancing is identical
        no matter the join order)."""
        if weight <= 0:
            raise ValueError("replica weight must be positive")
        if replica_id in self.weights:
            raise ValueError(f"replica {replica_id!r} already on ring")
        self.weights[replica_id] = float(weight)
        for v in range(self._vnode_count(weight)):
            h = stable_hash(f"{replica_id}#{v}")
            bisect.insort(self._points, (h, replica_id))
        self._rebuild_keys()

    def remove(self, replica_id: str) -> None:
        """Leave: deletes only this replica's points, so only its
        tenants remap."""
        if replica_id not in self.weights:
            raise KeyError(replica_id)
        del self.weights[replica_id]
        self.fenced.discard(replica_id)
        self._points = [(h, r) for h, r in self._points
                        if r != replica_id]
        self._rebuild_keys()

    # -- fencing (elastic membership) ---------------------------------------
    def fence(self, replica_id: str) -> None:
        """Exclude ``replica_id`` from routing without touching its
        points: tenants flow to the next point clockwise — exactly the
        owners a removal would pick — while the replica drains."""
        if replica_id not in self.weights:
            raise KeyError(replica_id)
        self.fenced.add(replica_id)

    def unfence(self, replica_id: str) -> None:
        """Restore a fenced replica to routing (mapping returns to the
        pre-fence assignment exactly — the points never moved)."""
        self.fenced.discard(replica_id)

    @property
    def routable_ids(self) -> List[str]:
        return sorted(r for r in self.weights if r not in self.fenced)

    def route(self, tenant: str) -> str:
        """First unfenced replica point clockwise from the tenant's
        hash."""
        chain = self.route_chain(tenant, 1)
        if not chain:
            raise RuntimeError("ring has no routable replicas")
        return chain[0]

    def route_chain(self, tenant: str, k: int) -> List[str]:
        """First ``k`` *distinct* unfenced replicas clockwise:
        ``[primary, backup, ...]``. Shorter when fewer than ``k``
        routable replicas exist."""
        if not self._points:
            return []
        k = min(k, len(self.weights) - len(self.fenced))
        start = bisect.bisect_right(self._keys, stable_hash(tenant))
        chain: List[str] = []
        n = len(self._points)
        for i in range(n):
            _, rid = self._points[(start + i) % n]
            if rid not in chain and rid not in self.fenced:
                chain.append(rid)
                if len(chain) == k:
                    break
        return chain

    def backup_for(self, tenant: str) -> Optional[str]:
        """The hedge target: next distinct replica after the primary
        (None with a single replica — hedging degenerates away)."""
        chain = self.route_chain(tenant, 2)
        return chain[1] if len(chain) > 1 else None

    def sibling_for(self, key: str, *,
                    exclude: Sequence[str] = ()) -> Optional[str]:
        """First distinct unfenced replica clockwise from ``key`` that
        is not in ``exclude`` — where selective stripe replication
        places a slow shard's mirror (the same clockwise walk a
        removal of the excluded owner would route the key to)."""
        skip = set(exclude)
        for rid in self.route_chain(key, len(self.weights)):
            if rid not in skip:
                return rid
        return None

    def assignments(self, tenants: Sequence[str]) -> Dict[str, str]:
        """tenant -> replica map for a batch of tenants (observability
        and rebalance planning)."""
        return {t: self.route(t) for t in tenants}

    def remap_diff(self, tenants: Sequence[str], *,
                   remove: Optional[str] = None,
                   add: Optional[Tuple[str, float]] = None
                   ) -> Dict[str, Tuple[str, str]]:
        """Plan a membership change without committing it.

        Returns ``{tenant: (old_owner, new_owner)}`` for exactly the
        tenants whose owner WOULD change if ``remove`` (a replica id)
        left and/or ``add`` (an ``(id, weight)`` pair) joined. Points
        are deterministic (md5 of stable strings), so the hypothetical
        membership is applied and rolled back exactly; fencing state is
        preserved."""
        if remove is None and add is None:
            return {}
        # Validate BEFORE mutating: a failed hypothetical apply must
        # leave the live ring untouched.
        if remove is not None and remove not in self.weights:
            raise KeyError(remove)
        if add is not None and add[0] in self.weights \
                and add[0] != remove:
            raise ValueError(f"replica {add[0]!r} already on ring")
        before = self.assignments(tenants)
        removed_weight = None
        removed_fenced = False
        if remove is not None:
            removed_weight = self.weights[remove]
            removed_fenced = remove in self.fenced
            self.remove(remove)
        if add is not None:
            self.add(*add)
        after = self.assignments(tenants)
        if add is not None:
            self.remove(add[0])
        if remove is not None:
            self.add(remove, removed_weight)
            if removed_fenced:
                self.fenced.add(remove)
        return {t: (before[t], after[t]) for t in tenants
                if after[t] != before[t]}
